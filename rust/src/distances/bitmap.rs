//! Fixed-size bitmaps + Simpson (overlap) distance — the paper's USPS setup:
//! 16x16 digit images discretized at 0.5, compared with
//! `1 - c(x & y) / min(c(x), c(y))` where `c` counts set bits.

/// A fixed-width bitmap stored as u64 words.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Bitmap {
    bits: usize,
    words: Vec<u64>,
}

impl Bitmap {
    pub fn zeros(bits: usize) -> Self {
        Bitmap { bits, words: vec![0; bits.div_ceil(64)] }
    }

    /// Build from a boolean slice.
    pub fn from_bools(bs: &[bool]) -> Self {
        let mut bm = Bitmap::zeros(bs.len());
        for (i, &b) in bs.iter().enumerate() {
            if b {
                bm.set(i);
            }
        }
        bm
    }

    /// Rebuild from raw parts (persistence). `words.len()` must equal
    /// `bits.div_ceil(64)`.
    pub fn from_raw(bits: usize, words: Vec<u64>) -> Self {
        assert_eq!(words.len(), bits.div_ceil(64), "word count mismatch");
        Bitmap { bits, words }
    }

    /// Build by thresholding a grayscale image (paper: threshold 0.5).
    pub fn from_grays(gs: &[f32], threshold: f32) -> Self {
        let mut bm = Bitmap::zeros(gs.len());
        for (i, &g) in gs.iter().enumerate() {
            if g >= threshold {
                bm.set(i);
            }
        }
        bm
    }

    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.bits);
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.bits);
        self.words[i / 64] >> (i % 64) & 1 == 1
    }

    #[inline]
    pub fn count(&self) -> u32 {
        self.words.iter().map(|w| w.count_ones()).sum()
    }

    pub fn len(&self) -> usize {
        self.bits
    }

    pub fn is_empty(&self) -> bool {
        self.bits == 0
    }

    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Expand to an f32 {0,1} vector (PJRT kernel path).
    pub fn to_f32(&self) -> Vec<f32> {
        (0..self.bits).map(|i| if self.get(i) { 1.0 } else { 0.0 }).collect()
    }

    #[inline]
    pub fn and_count(&self, other: &Bitmap) -> u32 {
        self.words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (a & b).count_ones())
            .sum()
    }
}

/// Simpson (overlap) distance: `1 - c(x & y) / min(c(x), c(y))`.
/// Empty bitmaps are at distance 1 from everything (no overlap evidence).
pub fn simpson(a: &Bitmap, b: &Bitmap) -> f64 {
    let (ca, cb) = (a.count(), b.count());
    let denom = ca.min(cb);
    if denom == 0 {
        return 1.0;
    }
    1.0 - a.and_count(b) as f64 / denom as f64
}

/// Jaccard distance over bitmaps (used by the lzjd fuzzy-hash simulant).
pub fn jaccard(a: &Bitmap, b: &Bitmap) -> f64 {
    let inter = a.and_count(b);
    let union = a.count() + b.count() - inter;
    if union == 0 {
        return 0.0;
    }
    1.0 - inter as f64 / union as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_count() {
        let mut b = Bitmap::zeros(256);
        assert_eq!(b.count(), 0);
        b.set(0);
        b.set(63);
        b.set(64);
        b.set(255);
        assert_eq!(b.count(), 4);
        assert!(b.get(63) && b.get(64) && !b.get(1));
    }

    #[test]
    fn from_bools_roundtrip() {
        let bools: Vec<bool> = (0..100).map(|i| i % 3 == 0).collect();
        let bm = Bitmap::from_bools(&bools);
        for (i, &x) in bools.iter().enumerate() {
            assert_eq!(bm.get(i), x);
        }
        let f = bm.to_f32();
        assert_eq!(f.len(), 100);
        assert_eq!(f.iter().filter(|&&v| v == 1.0).count() as u32, bm.count());
    }

    #[test]
    fn simpson_semantics() {
        let a = Bitmap::from_bools(&[true, true, false, false]);
        let sup = Bitmap::from_bools(&[true, true, true, false]);
        let dis = Bitmap::from_bools(&[false, false, true, true]);
        assert_eq!(simpson(&a, &sup), 0.0); // subset => 0
        assert_eq!(simpson(&a, &dis), 1.0); // disjoint => 1
        assert_eq!(simpson(&a, &a), 0.0);
        let empty = Bitmap::zeros(4);
        assert_eq!(simpson(&a, &empty), 1.0);
    }

    #[test]
    fn thresholding_matches_paper_rule() {
        let gs = [0.1f32, 0.5, 0.9, 0.49];
        let bm = Bitmap::from_grays(&gs, 0.5);
        assert_eq!(
            (0..4).map(|i| bm.get(i)).collect::<Vec<_>>(),
            vec![false, true, true, false]
        );
    }

    #[test]
    fn jaccard_bitmap() {
        let a = Bitmap::from_bools(&[true, true, false]);
        let b = Bitmap::from_bools(&[true, false, true]);
        assert!((jaccard(&a, &b) - (1.0 - 1.0 / 3.0)).abs() < 1e-12);
        let z = Bitmap::zeros(3);
        assert_eq!(jaccard(&z, &z), 0.0);
    }
}
