//! String distances (Finefoods reviews). Jaro-Winkler is the paper's choice
//! \[40\]; we add bounded Levenshtein as an alternative arbitrary metric for
//! the flexibility examples.

/// Jaro similarity between byte strings (ASCII-oriented, as is standard for
/// record-linkage uses; multi-byte UTF-8 is handled bytewise).
fn jaro(a: &[u8], b: &[u8]) -> f64 {
    if a.is_empty() && b.is_empty() {
        return 1.0;
    }
    if a.is_empty() || b.is_empty() {
        return 0.0;
    }
    let window = (a.len().max(b.len()) / 2).saturating_sub(1);
    let mut b_used = vec![false; b.len()];
    let mut matches = 0usize;
    // match pass
    let mut a_match = vec![false; a.len()];
    for (i, &ca) in a.iter().enumerate() {
        let lo = i.saturating_sub(window);
        let hi = (i + window + 1).min(b.len());
        for j in lo..hi {
            if !b_used[j] && b[j] == ca {
                b_used[j] = true;
                a_match[i] = true;
                matches += 1;
                break;
            }
        }
    }
    if matches == 0 {
        return 0.0;
    }
    // transposition pass
    let mut transpositions = 0usize;
    let mut j = 0usize;
    for (i, &m) in a_match.iter().enumerate() {
        if !m {
            continue;
        }
        while !b_used[j] {
            j += 1;
        }
        if a[i] != b[j] {
            transpositions += 1;
        }
        j += 1;
    }
    let m = matches as f64;
    (m / a.len() as f64 + m / b.len() as f64 + (m - transpositions as f64 / 2.0) / m)
        / 3.0
}

/// Jaro-Winkler *distance*: 1 - JW similarity, with the standard prefix
/// scale p = 0.1 and max prefix length 4.
pub fn jaro_winkler(a: &str, b: &str) -> f64 {
    let (a, b) = (a.as_bytes(), b.as_bytes());
    let j = jaro(a, b);
    let prefix = a
        .iter()
        .zip(b.iter())
        .take(4)
        .take_while(|(x, y)| x == y)
        .count() as f64;
    let sim = j + prefix * 0.1 * (1.0 - j);
    (1.0 - sim).clamp(0.0, 1.0)
}

/// Levenshtein distance normalized by max length, with an early-exit band:
/// returns 1.0 as soon as the edit distance provably exceeds
/// `cutoff_frac * max_len` (cheap filter for long texts).
pub fn levenshtein_norm(a: &str, b: &str, cutoff_frac: f64) -> f64 {
    let (a, b) = (a.as_bytes(), b.as_bytes());
    let (n, m) = (a.len(), b.len());
    if n == 0 && m == 0 {
        return 0.0;
    }
    let maxlen = n.max(m);
    let cutoff = ((maxlen as f64) * cutoff_frac).ceil() as usize;
    if n.abs_diff(m) > cutoff {
        return 1.0;
    }
    let mut prev: Vec<usize> = (0..=m).collect();
    let mut cur = vec![0usize; m + 1];
    for i in 1..=n {
        cur[0] = i;
        let mut row_min = cur[0];
        for j in 1..=m {
            let cost = usize::from(a[i - 1] != b[j - 1]);
            cur[j] = (prev[j] + 1).min(cur[j - 1] + 1).min(prev[j - 1] + cost);
            row_min = row_min.min(cur[j]);
        }
        if row_min > cutoff {
            return 1.0;
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    (prev[m] as f64 / maxlen as f64).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jw_identical_is_zero() {
        assert_eq!(jaro_winkler("martha", "martha"), 0.0);
        assert_eq!(jaro_winkler("", ""), 0.0);
    }

    #[test]
    fn jw_known_values() {
        // classic record-linkage examples
        let d = jaro_winkler("MARTHA", "MARHTA");
        assert!((d - (1.0 - 0.9611)).abs() < 1e-3, "got {d}");
        let d = jaro_winkler("DWAYNE", "DUANE");
        assert!((d - (1.0 - 0.8400)).abs() < 1e-3, "got {d}");
        let d = jaro_winkler("DIXON", "DICKSONX");
        assert!((d - (1.0 - 0.8133)).abs() < 1e-3, "got {d}");
    }

    #[test]
    fn jw_disjoint_is_one() {
        assert_eq!(jaro_winkler("abc", "xyz"), 1.0);
        assert_eq!(jaro_winkler("abc", ""), 1.0);
    }

    #[test]
    fn jw_symmetry_and_bounds() {
        let pairs = [("kitten", "sitting"), ("food review", "god review"), ("a", "ab")];
        for (a, b) in pairs {
            let d1 = jaro_winkler(a, b);
            let d2 = jaro_winkler(b, a);
            assert!((d1 - d2).abs() < 1e-12);
            assert!((0.0..=1.0).contains(&d1));
        }
    }

    #[test]
    fn levenshtein_basics() {
        assert_eq!(levenshtein_norm("kitten", "kitten", 1.0), 0.0);
        let d = levenshtein_norm("kitten", "sitting", 1.0);
        assert!((d - 3.0 / 7.0).abs() < 1e-12);
        // early exit band
        assert_eq!(levenshtein_norm("aaaaaaaaaa", "b", 0.2), 1.0);
    }
}
