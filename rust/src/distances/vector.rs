//! Dense-vector distances (Blobs, Household). Hot path: written as simple
//! indexed loops the compiler auto-vectorizes; chunked accumulation keeps
//! four independent dependency chains for better ILP.

/// Squared Euclidean distance. Accumulates in 4 f32 lanes (packed SIMD;
/// §Perf: +15-30% over f64-per-element accumulation, and 8 lanes measured
/// *worse* on short vectors) and widens once at the end; relative error
/// ≤ ~1e-6 at d ≤ 10⁴, far below clustering-relevant resolution.
#[inline]
pub fn sqeuclidean(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    const LANES: usize = 4;
    let mut acc = [0.0f32; LANES];
    let chunks = a.len() / LANES;
    for i in 0..chunks {
        let j = i * LANES;
        for l in 0..LANES {
            let d = a[j + l] - b[j + l];
            acc[l] += d * d;
        }
    }
    let mut s = 0.0f64;
    for l in 0..LANES {
        s += acc[l] as f64;
    }
    for i in chunks * LANES..a.len() {
        let d = (a[i] - b[i]) as f64;
        s += d * d;
    }
    s
}

/// Euclidean distance.
#[inline]
pub fn euclidean(a: &[f32], b: &[f32]) -> f64 {
    sqeuclidean(a, b).sqrt()
}

/// Cosine distance: 1 - cos-similarity. 0 for identical directions; returns
/// 1.0 when either vector is all-zero (no direction information).
#[inline]
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    const LANES: usize = 4;
    let mut dotl = [0.0f32; LANES];
    let mut nal = [0.0f32; LANES];
    let mut nbl = [0.0f32; LANES];
    let chunks = a.len() / LANES;
    for i in 0..chunks {
        let j = i * LANES;
        for l in 0..LANES {
            let (x, y) = (a[j + l], b[j + l]);
            dotl[l] += x * y;
            nal[l] += x * x;
            nbl[l] += y * y;
        }
    }
    let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for l in 0..LANES {
        dot += dotl[l] as f64;
        na += nal[l] as f64;
        nb += nbl[l] as f64;
    }
    for i in chunks * LANES..a.len() {
        let (x, y) = (a[i] as f64, b[i] as f64);
        dot += x * y;
        na += x * x;
        nb += y * y;
    }
    if na == 0.0 || nb == 0.0 {
        return 1.0;
    }
    (1.0 - dot / (na.sqrt() * nb.sqrt())).max(0.0)
}

/// Dot product (used by the PJRT-vs-native consistency tests).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn euclidean_basics() {
        assert_eq!(euclidean(&[0.0, 0.0], &[3.0, 4.0]), 5.0);
        assert_eq!(euclidean(&[1.0; 9], &[1.0; 9]), 0.0);
        assert_eq!(sqeuclidean(&[0.0; 5], &[1.0; 5]), 5.0);
    }

    #[test]
    fn euclidean_handles_tails() {
        // lengths not multiples of 4 exercise the remainder loop
        for n in [1, 2, 3, 5, 7, 13] {
            let a = vec![2.0f32; n];
            let b = vec![0.0f32; n];
            assert!((sqeuclidean(&a, &b) - 4.0 * n as f64).abs() < 1e-9);
        }
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[0.0, 1.0]) - 1.0).abs() < 1e-12);
        assert!(cosine(&[1.0, 2.0], &[2.0, 4.0]).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) - 2.0).abs() < 1e-12);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 1.0);
    }

    #[test]
    fn symmetry() {
        let a = [0.3f32, -1.2, 4.5, 0.0, 2.2];
        let b = [1.0f32, 0.7, -3.3, 9.1, -0.5];
        assert_eq!(euclidean(&a, &b), euclidean(&b, &a));
        assert_eq!(cosine(&a, &b), cosine(&b, &a));
    }
}
