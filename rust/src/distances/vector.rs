//! Dense-vector distances (Blobs, Household). Hot path: written as simple
//! indexed loops the compiler auto-vectorizes; chunked accumulation keeps
//! four independent dependency chains for better ILP. The scalar entry
//! points and the `*_batch` kernels share the same per-pair cores, so the
//! batch path is bit-identical to N scalar calls (pinned by the
//! `distance_batch` conformance property in `distances::tests`) — batching
//! buys amortized query-side work (one bounds-checked query borrow, one
//! hoisted query norm) and a branch-predictable inner loop, not a
//! different numeric result.

/// Accumulation lanes. 4 packed f32 lanes measured +15-30% over
/// f64-per-element accumulation, and 8 lanes measured *worse* on short
/// vectors.
const LANES: usize = 4;

/// Shared squared-distance core: 4 f32 lanes, widened once, f64 tail.
/// Relative error ≤ ~1e-6 at d ≤ 10⁴, far below clustering-relevant
/// resolution.
#[inline(always)]
fn sq_core(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; LANES];
    let chunks = a.len() / LANES;
    for i in 0..chunks {
        let j = i * LANES;
        for l in 0..LANES {
            let d = a[j + l] - b[j + l];
            acc[l] += d * d;
        }
    }
    let mut s = 0.0f64;
    for l in 0..LANES {
        s += acc[l] as f64;
    }
    for i in chunks * LANES..a.len() {
        let d = (a[i] - b[i]) as f64;
        s += d * d;
    }
    s
}

/// Shared dot / candidate-norm core for cosine: same lane structure as
/// [`sq_core`]. Splitting the query norm out (see [`norm_sq`]) keeps each
/// individual sum's accumulation order identical to the fused three-sum
/// loop it replaced, so `cosine` results are unchanged bit for bit.
#[inline(always)]
fn dot_nb_core(a: &[f32], b: &[f32]) -> (f64, f64) {
    debug_assert_eq!(a.len(), b.len());
    let mut dotl = [0.0f32; LANES];
    let mut nbl = [0.0f32; LANES];
    let chunks = a.len() / LANES;
    for i in 0..chunks {
        let j = i * LANES;
        for l in 0..LANES {
            let (x, y) = (a[j + l], b[j + l]);
            dotl[l] += x * y;
            nbl[l] += y * y;
        }
    }
    let (mut dot, mut nb) = (0.0f64, 0.0f64);
    for l in 0..LANES {
        dot += dotl[l] as f64;
        nb += nbl[l] as f64;
    }
    for i in chunks * LANES..a.len() {
        let (x, y) = (a[i] as f64, b[i] as f64);
        dot += x * y;
        nb += y * y;
    }
    (dot, nb)
}

/// Squared L2 norm with the same lane structure as the distance cores —
/// the hoistable query-side half of [`cosine`].
#[inline]
pub fn norm_sq(a: &[f32]) -> f64 {
    let mut acc = [0.0f32; LANES];
    let chunks = a.len() / LANES;
    for i in 0..chunks {
        let j = i * LANES;
        for l in 0..LANES {
            let x = a[j + l];
            acc[l] += x * x;
        }
    }
    let mut s = 0.0f64;
    for l in 0..LANES {
        s += acc[l] as f64;
    }
    for i in chunks * LANES..a.len() {
        let x = a[i] as f64;
        s += x * x;
    }
    s
}

/// Squared Euclidean distance (see [`sq_core`] for the accumulation
/// scheme).
#[inline]
pub fn sqeuclidean(a: &[f32], b: &[f32]) -> f64 {
    sq_core(a, b)
}

/// Euclidean distance.
#[inline]
pub fn euclidean(a: &[f32], b: &[f32]) -> f64 {
    sq_core(a, b).sqrt()
}

/// Cosine distance: 1 - cos-similarity. 0 for identical directions; returns
/// 1.0 when either vector is all-zero (no direction information).
#[inline]
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    cosine_with_qnorm(norm_sq(a), a, b)
}

/// Cosine distance with the query's squared norm precomputed — the batch
/// path hoists `norm_sq(q)` once per batch instead of once per pair.
/// `na` must equal `norm_sq(a)`.
#[inline]
pub fn cosine_with_qnorm(na: f64, a: &[f32], b: &[f32]) -> f64 {
    let (dot, nb) = dot_nb_core(a, b);
    if na == 0.0 || nb == 0.0 {
        return 1.0;
    }
    (1.0 - dot / (na.sqrt() * nb.sqrt())).max(0.0)
}

/// Dot product (used by the PJRT-vs-native consistency tests). Same
/// 4-lane chunked accumulation as the distance cores.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; LANES];
    let chunks = a.len() / LANES;
    for i in 0..chunks {
        let j = i * LANES;
        for l in 0..LANES {
            acc[l] += a[j + l] * b[j + l];
        }
    }
    let mut s = 0.0f64;
    for l in 0..LANES {
        s += acc[l] as f64;
    }
    for i in chunks * LANES..a.len() {
        s += a[i] as f64 * b[i] as f64;
    }
    s
}

// -------------------------------------------------------- batch kernels --

/// One query against many candidates, squared Euclidean. Bit-identical to
/// calling [`sqeuclidean`] per pair.
#[inline]
pub fn sqeuclidean_batch(q: &[f32], cands: &[&[f32]], out: &mut [f64]) {
    debug_assert_eq!(cands.len(), out.len());
    for (o, c) in out.iter_mut().zip(cands) {
        *o = sq_core(q, c);
    }
}

/// One query against many candidates, Euclidean. Bit-identical to calling
/// [`euclidean`] per pair.
#[inline]
pub fn euclidean_batch(q: &[f32], cands: &[&[f32]], out: &mut [f64]) {
    debug_assert_eq!(cands.len(), out.len());
    for (o, c) in out.iter_mut().zip(cands) {
        *o = sq_core(q, c).sqrt();
    }
}

/// One query against many candidates, cosine, with the query norm hoisted
/// out of the loop. Bit-identical to calling [`cosine`] per pair.
#[inline]
pub fn cosine_batch(q: &[f32], cands: &[&[f32]], out: &mut [f64]) {
    debug_assert_eq!(cands.len(), out.len());
    let nq = norm_sq(q);
    for (o, c) in out.iter_mut().zip(cands) {
        *o = cosine_with_qnorm(nq, q, c);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn euclidean_basics() {
        assert_eq!(euclidean(&[0.0, 0.0], &[3.0, 4.0]), 5.0);
        assert_eq!(euclidean(&[1.0; 9], &[1.0; 9]), 0.0);
        assert_eq!(sqeuclidean(&[0.0; 5], &[1.0; 5]), 5.0);
    }

    #[test]
    fn euclidean_handles_tails() {
        // lengths not multiples of 4 exercise the remainder loop
        for n in [1, 2, 3, 5, 7, 13] {
            let a = vec![2.0f32; n];
            let b = vec![0.0f32; n];
            assert!((sqeuclidean(&a, &b) - 4.0 * n as f64).abs() < 1e-9);
        }
    }

    #[test]
    fn dot_handles_tails() {
        // mirrors euclidean_handles_tails for the relaned dot product
        for n in [1, 2, 3, 5, 7, 13] {
            let a = vec![2.0f32; n];
            let b = vec![3.0f32; n];
            assert!((dot(&a, &b) - 6.0 * n as f64).abs() < 1e-9);
            let c: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let want: f64 = (0..n).map(|i| 2.0 * i as f64).sum();
            assert!((dot(&a, &c) - want).abs() < 1e-9);
        }
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[0.0, 1.0]) - 1.0).abs() < 1e-12);
        assert!(cosine(&[1.0, 2.0], &[2.0, 4.0]).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) - 2.0).abs() < 1e-12);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 1.0);
    }

    #[test]
    fn symmetry() {
        let a = [0.3f32, -1.2, 4.5, 0.0, 2.2];
        let b = [1.0f32, 0.7, -3.3, 9.1, -0.5];
        assert_eq!(euclidean(&a, &b), euclidean(&b, &a));
        assert_eq!(cosine(&a, &b), cosine(&b, &a));
    }

    #[test]
    fn batch_kernels_bit_match_scalar() {
        // the core guarantee the HNSW batch path is built on: the batch
        // kernels are the same arithmetic, not an approximation of it
        let mut rng = crate::util::rng::Rng::new(7);
        for dim in [1usize, 3, 4, 7, 16, 33] {
            let q: Vec<f32> = (0..dim).map(|_| rng.f32() - 0.5).collect();
            let cands: Vec<Vec<f32>> = (0..9)
                .map(|_| (0..dim).map(|_| rng.f32() - 0.5).collect())
                .collect();
            let refs: Vec<&[f32]> = cands.iter().map(|c| &c[..]).collect();
            let mut out = vec![0.0f64; refs.len()];
            sqeuclidean_batch(&q, &refs, &mut out);
            for (o, c) in out.iter().zip(&refs) {
                assert_eq!(o.to_bits(), sqeuclidean(&q, c).to_bits());
            }
            euclidean_batch(&q, &refs, &mut out);
            for (o, c) in out.iter().zip(&refs) {
                assert_eq!(o.to_bits(), euclidean(&q, c).to_bits());
            }
            cosine_batch(&q, &refs, &mut out);
            for (o, c) in out.iter().zip(&refs) {
                assert_eq!(o.to_bits(), cosine(&q, c).to_bits());
            }
        }
    }

    #[test]
    fn qnorm_split_matches_fused_cosine() {
        let a = [0.3f32, -1.2, 4.5, 0.0, 2.2, 1.1, -0.4];
        let b = [1.0f32, 0.7, -3.3, 9.1, -0.5, 0.0, 2.6];
        assert_eq!(
            cosine_with_qnorm(norm_sq(&a), &a, &b).to_bits(),
            cosine(&a, &b).to_bits()
        );
    }
}
