//! HDBSCAN* hierarchy extraction (McInnes & Healy \[26\]) and the exact
//! O(n²) HDBSCAN* baseline the paper compares against.
//!
//! Pipeline: minimum spanning forest over mutual-reachability weights →
//! single-linkage dendrogram ([`condense::Dendrogram`]) → condensed tree
//! with minimum cluster size m_cs ([`condense::CondensedTree`]) → flat
//! clusters by Excess-of-Mass stability selection ([`extract`]).

pub mod condense;
pub mod exact;
#[cfg(feature = "xla")]
pub mod exact_pjrt;
pub mod export;
pub mod extract;

pub use condense::{CondensedRow, CondensedTree, Dendrogram};
pub use export::{cluster_report, clustering_to_json, ClusterReport};
pub use extract::{
    extract_flat, extract_flat_opts, extract_hybrid, extract_leaf,
    ExtractionMode,
};

/// Final clustering output: flat labels + the full hierarchy.
#[derive(Clone, Debug)]
pub struct Clustering {
    /// Per-point flat cluster label; -1 = noise. Labels are dense 0..k.
    pub labels: Vec<i32>,
    /// Number of flat clusters.
    pub n_clusters: usize,
    /// The condensed hierarchy (for data exploration / Table 7 columns).
    pub condensed: CondensedTree,
    /// Selected condensed-cluster ids, index-aligned with flat labels.
    pub selected: Vec<u32>,
}

impl Clustering {
    /// Number of points assigned to a flat cluster (non-noise).
    pub fn n_clustered(&self) -> usize {
        self.labels.iter().filter(|&&l| l >= 0).count()
    }

    /// Number of clusters in the hierarchy (condensed clusters, root
    /// excluded) — the paper's "hierarchical clusters" column.
    pub fn n_hierarchical_clusters(&self) -> usize {
        self.condensed.n_clusters_excluding_root()
    }

    /// Number of points that belong to at least one non-root hierarchical
    /// cluster — the paper's "hierarchical clustered elements" column
    /// ("almost all elements end up in a cluster when we consider the
    /// hierarchical clustering", §4.3).
    pub fn n_hierarchical_clustered(&self) -> usize {
        self.condensed.n_points_in_non_root_clusters()
    }

    /// Cluster sizes of the flat clustering.
    pub fn cluster_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.n_clusters];
        for &l in &self.labels {
            if l >= 0 {
                sizes[l as usize] += 1;
            }
        }
        sizes
    }
}

/// Run the full extraction pipeline from MSF edges (the shared back half of
/// both FISHDBC and the exact baseline).
pub fn cluster_from_msf(
    edges: &[crate::mst::Edge],
    n_points: usize,
    mcs: usize,
) -> Clustering {
    cluster_from_msf_opts(edges, n_points, mcs, false)
}

/// [`cluster_from_msf`] with `allow_single_cluster` (hdbscan's option for
/// datasets that form one uniform cluster; default-off everywhere).
pub fn cluster_from_msf_opts(
    edges: &[crate::mst::Edge],
    n_points: usize,
    mcs: usize,
    allow_single_cluster: bool,
) -> Clustering {
    let dendro = Dendrogram::from_msf(edges, n_points);
    let condensed = CondensedTree::from_dendrogram(&dendro, mcs);
    extract::extract_flat_opts(&condensed, allow_single_cluster)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mst::Edge;

    /// Two well-separated chains of 5 points each.
    fn two_chain_edges() -> (Vec<Edge>, usize) {
        let mut edges = Vec::new();
        for i in 0..4u32 {
            edges.push(Edge::new(i, i + 1, 1.0));
            edges.push(Edge::new(5 + i, 5 + i + 1, 1.0));
        }
        edges.push(Edge::new(4, 5, 50.0)); // weak bridge
        (edges, 10)
    }

    #[test]
    fn two_clusters_found() {
        let (edges, n) = two_chain_edges();
        let c = cluster_from_msf(&edges, n, 3);
        assert_eq!(c.labels.len(), n);
        assert_eq!(c.n_clusters, 2, "labels: {:?}", c.labels);
        // points 0-4 together, 5-9 together, different labels
        for i in 1..5 {
            assert_eq!(c.labels[i], c.labels[0]);
            assert_eq!(c.labels[5 + i - 1], c.labels[5]);
        }
        assert_ne!(c.labels[0], c.labels[5]);
        assert_eq!(c.n_clustered(), 10);
    }

    #[test]
    fn forest_components_cluster_independently() {
        // same two chains but NO bridge: a true forest
        let (mut edges, n) = two_chain_edges();
        edges.pop();
        let c = cluster_from_msf(&edges, n, 3);
        assert_eq!(c.n_clusters, 2);
        assert_ne!(c.labels[0], c.labels[5]);
    }

    #[test]
    fn all_noise_when_mcs_too_large() {
        let edges = vec![Edge::new(0, 1, 1.0)];
        let c = cluster_from_msf(&edges, 3, 3);
        // 3 points, biggest component is 2 < mcs=3 ... wait component {0,1}
        // has size 2 and point 2 is isolated: no cluster of size >= 3.
        assert_eq!(c.n_clusters, 0);
        assert!(c.labels.iter().all(|&l| l == -1));
    }

    #[test]
    fn singleton_dataset() {
        let c = cluster_from_msf(&[], 1, 2);
        assert_eq!(c.labels, vec![-1]);
        assert_eq!(c.n_clusters, 0);
    }
}
