//! Hierarchy export and per-cluster reporting — the data-exploration side
//! of the paper's *hierarchical* axis ("users can group and/or divide
//! clusters in sub- or super-clusters when data exploration requires so",
//! §1).
//!
//! Formats: JSON (machine-readable condensed tree + selection), GraphViz
//! DOT (cluster tree rendering), Newick (dendrogram interchange with
//! phylogenetics/scipy tooling), plus a [`ClusterReport`] table with the
//! birth/death densities, stability and persistence of every condensed
//! cluster.

use std::fmt::Write as _;

use super::condense::Dendrogram;
use super::Clustering;

/// Per-cluster summary row (see [`cluster_report`]).
#[derive(Clone, Debug)]
pub struct ClusterReport {
    /// Condensed cluster id (root = n_points).
    pub id: u32,
    /// Parent cluster id (root's parent = itself).
    pub parent: u32,
    /// Points that ever belonged to this cluster.
    pub size: u32,
    /// Density at which the cluster is born (λ = 1/distance).
    pub birth_lambda: f64,
    /// Density at which it dies (splits or dissolves); ∞ for leaves that
    /// never split further than point fall-out.
    pub death_lambda: f64,
    /// Excess-of-Mass stability (the flat-selection score).
    pub stability: f64,
    /// Whether the flat extraction selected it.
    pub selected: bool,
    /// Depth below the root cluster.
    pub depth: u32,
}

/// Build the per-cluster report for a clustering (sorted by id: parents
/// before children).
pub fn cluster_report(c: &Clustering) -> Vec<ClusterReport> {
    let tree = &c.condensed;
    let n = tree.n_points as u32;
    let k = tree.n_cluster_ids;
    let birth = tree.birth_lambdas();
    let stability = tree.stabilities();

    let mut parent = vec![n; k];
    let mut size = vec![0u32; k];
    let mut death = vec![f64::INFINITY; k];
    for r in &tree.rows {
        let pidx = (r.parent - n) as usize;
        if r.child >= n {
            let cidx = (r.child - n) as usize;
            parent[cidx] = r.parent;
            // a parent that spawns child clusters dies at that λ
            let d = &mut death[pidx];
            *d = if d.is_infinite() { r.lambda } else { d.max(r.lambda) };
        } else {
            size[pidx] += 1;
        }
    }
    // size = own fall-outs + recursive children sizes ("ever belonged");
    // ids ascend parent→child, so a reverse pass accumulates bottom-up
    for idx in (1..k).rev() {
        let p = (parent[idx] - n) as usize;
        size[p] += size[idx];
    }

    let mut depth = vec![0u32; k];
    for idx in 1..k {
        depth[idx] = depth[(parent[idx] - n) as usize] + 1;
    }

    (0..k)
        .map(|idx| ClusterReport {
            id: n + idx as u32,
            parent: parent[idx],
            size: size[idx],
            birth_lambda: birth[idx],
            death_lambda: death[idx],
            stability: stability[idx],
            selected: c.selected.contains(&(n + idx as u32)),
            depth: depth[idx],
        })
        .collect()
}

/// Render the report as an indented text tree (CLI `export --format tree`).
pub fn report_to_text(report: &[ClusterReport]) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<8} {:>8} {:>12} {:>12} {:>12} {:>9}",
        "cluster", "size", "birth λ", "death λ", "stability", "selected"
    );
    for r in report {
        let indent = "  ".repeat(r.depth as usize);
        let _ = writeln!(
            out,
            "{:<8} {:>8} {:>12.4} {:>12.4} {:>12.4} {:>9}",
            format!("{indent}{}", r.id),
            r.size,
            r.birth_lambda,
            r.death_lambda,
            r.stability,
            if r.selected { "*" } else { "" }
        );
    }
    out
}

/// Escape a string for JSON (we emit JSON by hand: no serde offline).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

fn json_f64(x: f64) -> String {
    if x.is_infinite() {
        if x > 0.0 { "1e308".into() } else { "-1e308".into() }
    } else if x.is_nan() {
        "null".into()
    } else {
        format!("{x}")
    }
}

/// Serialize a clustering (flat labels + condensed tree + selection +
/// per-cluster report) to a single JSON document.
pub fn clustering_to_json(c: &Clustering, name: &str) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    let _ = writeln!(out, "  \"name\": \"{}\",", json_escape(name));
    let _ = writeln!(out, "  \"n_points\": {},", c.labels.len());
    let _ = writeln!(out, "  \"n_clusters\": {},", c.n_clusters);
    let _ = writeln!(
        out,
        "  \"labels\": [{}],",
        c.labels.iter().map(|l| l.to_string()).collect::<Vec<_>>().join(",")
    );
    let _ = writeln!(
        out,
        "  \"selected\": [{}],",
        c.selected.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(",")
    );
    out.push_str("  \"condensed_tree\": [\n");
    for (i, r) in c.condensed.rows.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"parent\": {}, \"child\": {}, \"lambda\": {}, \"size\": {}}}",
            r.parent,
            r.child,
            json_f64(r.lambda),
            r.size
        );
        out.push_str(if i + 1 < c.condensed.rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n");
    out.push_str("  \"clusters\": [\n");
    let report = cluster_report(c);
    for (i, r) in report.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"id\": {}, \"parent\": {}, \"size\": {}, \"birth_lambda\": {}, \
             \"death_lambda\": {}, \"stability\": {}, \"selected\": {}, \"depth\": {}}}",
            r.id,
            r.parent,
            r.size,
            json_f64(r.birth_lambda),
            json_f64(r.death_lambda),
            json_f64(r.stability),
            r.selected,
            r.depth
        );
        out.push_str(if i + 1 < report.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

/// GraphViz DOT rendering of the condensed cluster tree (clusters only;
/// point fall-outs are summarized as a count per cluster).
pub fn condensed_to_dot(c: &Clustering) -> String {
    let tree = &c.condensed;
    let n = tree.n_points as u32;
    let report = cluster_report(c);
    let mut out = String::from("digraph condensed {\n  rankdir=TB;\n  node [shape=box];\n");
    for r in &report {
        let color = if r.selected { ", style=filled, fillcolor=lightblue" } else { "" };
        let _ = writeln!(
            out,
            "  c{} [label=\"#{}\\nsize {}\\nλ {:.3}→{:.3}\\nstab {:.3}\"{}];",
            r.id, r.id, r.size, r.birth_lambda, r.death_lambda, r.stability, color
        );
        if r.id != n {
            let _ = writeln!(out, "  c{} -> c{};", r.parent, r.id);
        }
    }
    out.push_str("}\n");
    out
}

/// Newick serialization of a single-linkage dendrogram (leaf names are
/// point ids; branch lengths are merge distances, ∞ clamped). Suitable for
/// scipy / ete3 / iTOL.
pub fn dendrogram_to_newick(d: &Dendrogram) -> String {
    fn rec(d: &Dendrogram, node: u32, parent_dist: f64, out: &mut String) {
        let n = d.n_points as u32;
        let dist = |x: f64| if x.is_finite() { x } else { 1e308 };
        if node < n {
            let _ = write!(out, "{}:{}", node, dist(parent_dist));
            return;
        }
        let (l, r, w, _) = d
            .merges
            .get((node - n) as usize)
            .copied()
            .expect("internal node");
        out.push('(');
        rec(d, l, w, out);
        out.push(',');
        rec(d, r, w, out);
        let _ = write!(out, "):{}", dist(parent_dist));
    }
    let mut out = String::new();
    if d.n_points == 1 {
        return "(0:0);".into();
    }
    rec(d, d.root(), 0.0, &mut out);
    out.push(';');
    out
}

/// Parse-free structural validation of our own JSON (tests + a cheap
/// defence against emitting malformed output): bracket balance and quote
/// pairing.
pub fn json_is_balanced(s: &str) -> bool {
    let mut depth: i64 = 0;
    let mut in_str = false;
    let mut esc = false;
    for ch in s.chars() {
        if in_str {
            if esc {
                esc = false;
            } else if ch == '\\' {
                esc = true;
            } else if ch == '"' {
                in_str = false;
            }
            continue;
        }
        match ch {
            '"' => in_str = true,
            '{' | '[' => depth += 1,
            '}' | ']' => {
                depth -= 1;
                if depth < 0 {
                    return false;
                }
            }
            _ => {}
        }
    }
    depth == 0 && !in_str
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdbscan::cluster_from_msf;
    use crate::mst::Edge;

    fn sample_clustering() -> Clustering {
        // two chains of 6 + a weak bridge
        let mut edges = Vec::new();
        for i in 0..5u32 {
            edges.push(Edge::new(i, i + 1, 1.0));
            edges.push(Edge::new(6 + i, 7 + i, 1.0));
        }
        edges.push(Edge::new(5, 6, 40.0));
        cluster_from_msf(&edges, 12, 3)
    }

    #[test]
    fn report_covers_all_clusters_and_sizes_nest() {
        let c = sample_clustering();
        let rep = cluster_report(&c);
        assert_eq!(rep.len(), c.condensed.n_cluster_ids);
        // root first, full size
        assert_eq!(rep[0].id, c.condensed.root());
        assert_eq!(rep[0].size as usize, 12);
        assert_eq!(rep[0].depth, 0);
        for r in &rep[1..] {
            let parent = &rep[(r.parent - c.condensed.root()) as usize];
            assert!(r.size <= parent.size, "child bigger than parent");
            assert_eq!(r.depth, parent.depth + 1);
            assert!(r.birth_lambda >= parent.birth_lambda);
        }
        // selected ids in the report match the clustering
        let sel: Vec<u32> =
            rep.iter().filter(|r| r.selected).map(|r| r.id).collect();
        assert_eq!(sel, c.selected);
    }

    #[test]
    fn json_well_formed_and_complete() {
        let c = sample_clustering();
        let j = clustering_to_json(&c, "unit \"test\"");
        assert!(json_is_balanced(&j), "unbalanced JSON:\n{j}");
        assert!(j.contains("\"n_points\": 12"));
        assert!(j.contains("unit \\\"test\\\""));
        assert!(j.contains("\"condensed_tree\""));
        // one label per point
        let labels_part = j.split("\"labels\": [").nth(1).unwrap();
        let labels_csv = labels_part.split(']').next().unwrap();
        assert_eq!(labels_csv.split(',').count(), 12);
    }

    #[test]
    fn dot_contains_every_cluster_edge() {
        let c = sample_clustering();
        let dot = condensed_to_dot(&c);
        assert!(dot.starts_with("digraph"));
        for r in cluster_report(&c) {
            assert!(dot.contains(&format!("c{} [", r.id)));
            if r.id != c.condensed.root() {
                assert!(dot.contains(&format!("c{} -> c{};", r.parent, r.id)));
            }
        }
    }

    #[test]
    fn newick_balanced_and_has_all_leaves() {
        let mut edges = Vec::new();
        for i in 0..7u32 {
            edges.push(Edge::new(i, i + 1, (i + 1) as f64));
        }
        let d = Dendrogram::from_msf(&edges, 8);
        let nw = dendrogram_to_newick(&d);
        assert!(nw.ends_with(';'));
        assert_eq!(
            nw.chars().filter(|&c| c == '(').count(),
            nw.chars().filter(|&c| c == ')').count()
        );
        for leaf in 0..8 {
            assert!(
                nw.contains(&format!("{leaf}:")),
                "leaf {leaf} missing in {nw}"
            );
        }
    }

    #[test]
    fn newick_singleton() {
        let d = Dendrogram::from_msf(&[], 1);
        assert_eq!(dendrogram_to_newick(&d), "(0:0);");
    }

    #[test]
    fn forest_infinity_merges_survive_export() {
        // two disconnected components: ∞ merges must not break JSON/newick
        let edges = vec![Edge::new(0, 1, 1.0), Edge::new(2, 3, 1.0)];
        let c = cluster_from_msf(&edges, 4, 2);
        let j = clustering_to_json(&c, "forest");
        assert!(json_is_balanced(&j));
        assert!(!j.contains("inf"), "raw inf leaked into JSON");
        let d = Dendrogram::from_msf(&edges, 4);
        let nw = dendrogram_to_newick(&d);
        assert!(!nw.contains("inf"));
    }
}
