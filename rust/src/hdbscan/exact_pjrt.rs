//! Exact HDBSCAN\* with the distance blocks executed by the **compiled
//! JAX/Pallas kernels** through PJRT — the three-layer stack on the
//! algorithm path, not just in examples. Same algorithm as
//! [`super::exact`]: core distances, then Prim over the implicit
//! mutual-reachability graph — but every O(B²) distance block and every
//! fused mutual-reachability row comes from `artifacts/*.hlo.txt`.
//!
//! This is the "kernel backend" of the native-vs-PJRT ablation: at small
//! block sizes the PJRT round trip dominates (EXPERIMENTS.md §Perf), while
//! on accelerator targets the same artifacts run unchanged — the rust side
//! only ever sees padded `[B, D]` buffers.

use anyhow::{anyhow, Result};

use crate::distances::Item;
use crate::fishdbc::neighbors::KBest;
use crate::hdbscan::{cluster_from_msf, Clustering};
use crate::mst::Edge;
use crate::runtime::Runtime;

/// Result of the PJRT-backed exact baseline.
#[derive(Debug)]
pub struct PjrtExactResult {
    pub clustering: Clustering,
    /// PJRT executions performed (the backend's cost unit — each one
    /// evaluates up to B×B distances).
    pub kernel_execs: u64,
}

/// Run exact HDBSCAN\* over dense items using the compiled `pairwise_*`
/// and `mreach_*` modules for `metric_name` ("euclidean" or "cosine").
///
/// Requires every item to be [`Item::Dense`] with dim ≤ the loaded
/// module's D; fails (never panics) otherwise.
pub fn exact_hdbscan_pjrt(
    items: &[Item],
    rt: &Runtime,
    metric_name: &str,
    min_pts: usize,
    mcs: usize,
) -> Result<PjrtExactResult> {
    let n = items.len();
    if n == 0 {
        return Ok(PjrtExactResult {
            clustering: cluster_from_msf(&[], 1, mcs),
            kernel_execs: 0,
        });
    }
    let rows: Vec<&[f32]> = items
        .iter()
        .map(|it| match it {
            Item::Dense(v) => Ok(v.as_slice()),
            other => Err(anyhow!("exact_pjrt needs dense items, got {other:?}")),
        })
        .collect::<Result<_>>()?;
    let dim = rows.iter().map(|r| r.len()).max().unwrap_or(0);

    let pw = rt
        .find_module("pairwise", metric_name, dim)
        .ok_or_else(|| anyhow!("no pairwise_{metric_name} module for dim {dim}"))?
        .clone_meta();
    let mr = rt
        .find_module("mreach", metric_name, dim)
        .ok_or_else(|| anyhow!("no mreach_{metric_name} module for dim {dim}"))?
        .clone_meta();
    let b = pw.0;
    let execs0 = rt.exec_count();

    // --- core distances: k-th closest neighbor (self excluded), computed
    // from B×B pairwise kernel blocks.
    let k = min_pts.min(n.saturating_sub(1)).max(1);
    let mut best: Vec<KBest> = vec![KBest::default(); n];
    let blocks: Vec<(usize, usize)> = (0..n)
        .step_by(b)
        .map(|s| (s, (s + b).min(n)))
        .collect();
    for &(xi, xe) in &blocks {
        for &(yi, ye) in &blocks {
            let block = rt.pairwise(&pw.1, &rows[xi..xe], &rows[yi..ye])?;
            for (i, row) in block.iter().enumerate() {
                let gi = xi + i;
                for (j, &d) in row.iter().enumerate() {
                    let gj = yi + j;
                    if gi != gj {
                        best[gi].offer(k, gj as u32, d as f64);
                    }
                }
            }
        }
    }
    let core: Vec<f32> = best.iter().map(|kb| kb.core(k) as f32).collect();
    drop(best);

    // --- Prim over the implicit mutual-reachability graph, one fused
    // mreach row (max(d, core_i, core_j), computed in-kernel) at a time.
    let mut in_tree = vec![false; n];
    let mut best_d = vec![f64::INFINITY; n];
    let mut best_from = vec![0u32; n];
    let mut edges: Vec<Edge> = Vec::with_capacity(n - 1);
    let mut current = 0usize;
    in_tree[0] = true;
    for _ in 1..n {
        let crow = [rows[current]];
        let ccore = [core[current]];
        for &(yi, ye) in &blocks {
            let mrow =
                rt.mreach(&mr.1, &crow, &rows[yi..ye], &ccore, &core[yi..ye])?;
            for (j, &d) in mrow[0].iter().enumerate() {
                let gj = yi + j;
                if !in_tree[gj] && (d as f64) < best_d[gj] {
                    best_d[gj] = d as f64;
                    best_from[gj] = current as u32;
                }
            }
        }
        // next: cheapest frontier node
        let mut next = usize::MAX;
        let mut next_d = f64::INFINITY;
        for j in 0..n {
            if !in_tree[j] && best_d[j] < next_d {
                next_d = best_d[j];
                next = j;
            }
        }
        if next == usize::MAX {
            break; // disconnected (cannot happen for finite metrics)
        }
        edges.push(Edge::new(best_from[next], next as u32, next_d));
        in_tree[next] = true;
        current = next;
    }

    Ok(PjrtExactResult {
        clustering: cluster_from_msf(&edges, n, mcs),
        kernel_execs: rt.exec_count() - execs0,
    })
}

/// (b, name) pair cloned out of a `ModuleMeta` borrow.
trait CloneMeta {
    fn clone_meta(&self) -> (usize, String);
}

impl CloneMeta for crate::runtime::ModuleMeta {
    fn clone_meta(&self) -> (usize, String) {
        (self.b, self.name.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets;
    use crate::hdbscan::exact::{exact_hdbscan, ExactParams};
    use crate::metrics::adjusted_mutual_info;
    use crate::runtime::default_artifacts_dir;

    fn runtime_or_skip() -> Option<Runtime> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.tsv").exists() {
            eprintln!("SKIP exact_pjrt tests — run `make artifacts`");
            return None;
        }
        Some(Runtime::load(&dir).expect("artifacts exist but failed to load"))
    }

    #[test]
    fn pjrt_baseline_matches_native_exact() {
        let Some(rt) = runtime_or_skip() else { return };
        let ds = datasets::blobs::generate(300, 16, 4, 9);

        let native = exact_hdbscan(
            &ds.items,
            &ds.metric,
            ExactParams { min_pts: 10, mcs: 10, matrix_budget: None },
        )
        .unwrap();
        let pjrt =
            exact_hdbscan_pjrt(&ds.items, &rt, "euclidean", 10, 10).unwrap();

        assert_eq!(
            pjrt.clustering.n_clusters,
            native.clustering.n_clusters
        );
        // f32 kernels vs f64 native: tie-breaks may differ, structure not
        let native_pred: Vec<usize> =
            native.clustering.labels.iter().map(|&l| (l + 1) as usize).collect();
        let pjrt_pred: Vec<usize> =
            pjrt.clustering.labels.iter().map(|&l| (l + 1) as usize).collect();
        let ami = adjusted_mutual_info(&pjrt_pred, &native_pred);
        assert!(ami > 0.99, "PJRT vs native AMI {ami}");
        assert!(pjrt.kernel_execs > 0);
    }

    #[test]
    fn pjrt_baseline_rejects_non_dense() {
        let Some(rt) = runtime_or_skip() else { return };
        let items = vec![crate::distances::Item::Text("x".into())];
        assert!(exact_hdbscan_pjrt(&items, &rt, "euclidean", 2, 2).is_err());
    }

    #[test]
    fn pjrt_empty_input() {
        let Some(rt) = runtime_or_skip() else { return };
        let r = exact_hdbscan_pjrt(&[], &rt, "euclidean", 5, 5).unwrap();
        assert_eq!(r.clustering.n_clusters, 0);
        assert_eq!(r.kernel_execs, 0);
    }
}
