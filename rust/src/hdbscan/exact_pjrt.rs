//! Exact HDBSCAN\* with the distance blocks executed by the **compiled
//! JAX/Pallas kernels** through PJRT — the three-layer stack on the
//! algorithm path, not just in examples. Same algorithm as
//! [`super::exact`]: core distances, then Prim over the implicit
//! mutual-reachability graph — but every O(B²) distance block and every
//! fused mutual-reachability row comes from `artifacts/*.hlo.txt`.
//!
//! This is the "kernel backend" of the native-vs-PJRT ablation: at small
//! block sizes the PJRT round trip dominates (EXPERIMENTS.md §Perf), while
//! on accelerator targets the same artifacts run unchanged — the rust side
//! only ever sees padded `[B, D]` buffers.

use std::cell::RefCell;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::distances::{Item, Metric};
use crate::fishdbc::neighbors::KBest;
use crate::hdbscan::{cluster_from_msf, Clustering};
use crate::mst::Edge;
use crate::runtime::Runtime;

/// Chunked one-query-×-many-candidates evaluation through the compiled
/// `pairwise_*` module: the PJRT instantiation of the
/// [`Metric::distance_batch`] contract. Each ≤B-candidate chunk is one
/// kernel execution; a backend failure degrades that chunk to `NaN`
/// ("unknown"), which the algorithm's [`sanitize_distance`]
/// (`crate::distances::sanitize_distance`) choke points map to `+inf` —
/// a failing accelerator makes results conservative, never corrupt.
fn pairwise_batch_into(
    rt: &Runtime,
    module: &str,
    b: usize,
    q: &Item,
    cands: &[&Item],
    out: &mut [f64],
) {
    debug_assert_eq!(cands.len(), out.len());
    let qrow = [q.as_dense()];
    let mut done = 0usize;
    for chunk in cands.chunks(b.max(1)) {
        let ys: Vec<&[f32]> = chunk.iter().map(|c| c.as_dense()).collect();
        match rt.pairwise(module, &qrow, &ys) {
            Ok(rows) => {
                for (j, &d) in rows[0].iter().enumerate() {
                    out[done + j] = d as f64;
                }
            }
            Err(_) => out[done..done + chunk.len()].fill(f64::NAN),
        }
        done += chunk.len();
    }
}

/// Borrow-based batch adapter over one loaded [`Runtime`]: the dense PJRT
/// path expressed as the `distance_batch` hook. [`exact_hdbscan_pjrt`]
/// routes its core-distance blocks through this adapter, so the exact
/// baseline and any batch caller share one kernel entry.
///
/// The inherent `dist`/`distance_batch` mirror the [`Metric`] contract
/// exactly (batch ≡ N× dist), but the *trait* cannot be implemented for a
/// `&Runtime`-holding type — `Metric: Send + Sync` (metrics are shared
/// across shard threads) while PJRT client handles are thread-confined.
/// [`PjrtMetric`] is the trait-implementing owner for that use.
pub struct PjrtBatchMetric<'rt> {
    rt: &'rt Runtime,
    module: String,
    b: usize,
}

impl<'rt> PjrtBatchMetric<'rt> {
    /// Bind to the `pairwise_<metric_name>` module covering `dim`.
    pub fn new(rt: &'rt Runtime, metric_name: &str, dim: usize) -> Result<Self> {
        let (b, module) = rt
            .find_module("pairwise", metric_name, dim)
            .ok_or_else(|| {
                anyhow!("no pairwise_{metric_name} module for dim {dim}")
            })?
            .clone_meta();
        Ok(PjrtBatchMetric { rt, module, b })
    }

    /// Kernel block size B (one execution covers up to B×B pairs).
    pub fn block(&self) -> usize {
        self.b
    }

    /// [`Metric::dist`]-shaped scalar evaluation (one 1×1 kernel exec).
    pub fn dist(&self, a: &Item, b: &Item) -> f64 {
        let mut out = [0.0f64];
        self.distance_batch(a, &[b], &mut out);
        out[0]
    }

    /// [`Metric::distance_batch`]-shaped batch evaluation.
    pub fn distance_batch(&self, q: &Item, cands: &[&Item], out: &mut [f64]) {
        pairwise_batch_into(self.rt, &self.module, self.b, q, cands, out);
    }

    /// Full-block entry for the exact baseline's core-distance stage:
    /// one ≤B×B kernel execution per call (callers tile larger inputs),
    /// preserving the B×B exec count of the hand-rolled loop it replaced.
    pub fn distance_block(
        &self,
        xs: &[&[f32]],
        ys: &[&[f32]],
    ) -> Result<Vec<Vec<f32>>> {
        self.rt.pairwise(&self.module, xs, ys)
    }
}

thread_local! {
    /// Per-thread runtime cache for [`PjrtMetric`]: PJRT client handles
    /// are neither `Send` nor `Sync`, so each thread that evaluates
    /// distances loads (and keeps) its own runtime, keyed by artifacts
    /// dir.
    static THREAD_RT: RefCell<Option<(PathBuf, Runtime)>> =
        const { RefCell::new(None) };
}

/// Owned, `Send + Sync` PJRT metric: the accelerated instantiation of
/// [`Metric::distance_batch`], usable anywhere a `Metric<Item>` is (the
/// engine hands clones to its shard threads; each thread lazily loads a
/// thread-local [`Runtime`] from the artifacts dir). Scalar `dist` is a
/// 1-candidate batch, so batch ≡ N× dist holds by construction.
#[derive(Clone)]
pub struct PjrtMetric {
    dir: PathBuf,
    module: String,
    b: usize,
    dim: usize,
}

impl PjrtMetric {
    /// Validate the artifacts dir and bind the `pairwise_<metric_name>`
    /// module covering `dim` (loads a runtime once to resolve it; worker
    /// threads load their own lazily).
    pub fn new(
        dir: impl AsRef<Path>,
        metric_name: &str,
        dim: usize,
    ) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let rt = Runtime::load(&dir)?;
        let (b, module) = rt
            .find_module("pairwise", metric_name, dim)
            .ok_or_else(|| {
                anyhow!("no pairwise_{metric_name} module for dim {dim}")
            })?
            .clone_meta();
        Ok(PjrtMetric { dir, module, b, dim })
    }

    fn with_runtime<R>(&self, f: impl FnOnce(&Runtime) -> R) -> Result<R> {
        THREAD_RT.with(|slot| {
            let mut slot = slot.borrow_mut();
            let stale =
                !matches!(&*slot, Some((dir, _)) if *dir == self.dir);
            if stale {
                *slot = Some((self.dir.clone(), Runtime::load(&self.dir)?));
            }
            let (_, rt) = slot.as_ref().expect("runtime just installed");
            Ok(f(rt))
        })
    }
}

impl Metric<Item> for PjrtMetric {
    fn dist(&self, a: &Item, b: &Item) -> f64 {
        let mut out = [0.0f64];
        self.distance_batch(a, &[b], &mut out);
        out[0]
    }

    fn distance_batch(&self, q: &Item, cands: &[&Item], out: &mut [f64]) {
        if cands.is_empty() {
            return;
        }
        // a thread that cannot load the runtime evaluates to NaN →
        // sanitized to +inf at the choke points (conservative, not wrong)
        let ran = self.with_runtime(|rt| {
            pairwise_batch_into(rt, &self.module, self.b, q, cands, out);
        });
        if ran.is_err() {
            out.fill(f64::NAN);
        }
    }

    fn check_item(&self, item: &Item) {
        match item {
            Item::Dense(v) => assert!(
                v.len() <= self.dim,
                "item dim {} exceeds module dim {}",
                v.len(),
                self.dim
            ),
            other => panic!("PjrtMetric needs dense items, got {other:?}"),
        }
    }
}

/// Result of the PJRT-backed exact baseline.
#[derive(Debug)]
pub struct PjrtExactResult {
    pub clustering: Clustering,
    /// PJRT executions performed (the backend's cost unit — each one
    /// evaluates up to B×B distances).
    pub kernel_execs: u64,
}

/// Run exact HDBSCAN\* over dense items using the compiled `pairwise_*`
/// and `mreach_*` modules for `metric_name` ("euclidean" or "cosine").
///
/// Requires every item to be [`Item::Dense`] with dim ≤ the loaded
/// module's D; fails (never panics) otherwise.
pub fn exact_hdbscan_pjrt(
    items: &[Item],
    rt: &Runtime,
    metric_name: &str,
    min_pts: usize,
    mcs: usize,
) -> Result<PjrtExactResult> {
    let n = items.len();
    if n == 0 {
        return Ok(PjrtExactResult {
            clustering: cluster_from_msf(&[], 1, mcs),
            kernel_execs: 0,
        });
    }
    let rows: Vec<&[f32]> = items
        .iter()
        .map(|it| match it {
            Item::Dense(v) => Ok(v.as_slice()),
            other => Err(anyhow!("exact_pjrt needs dense items, got {other:?}")),
        })
        .collect::<Result<_>>()?;
    let dim = rows.iter().map(|r| r.len()).max().unwrap_or(0);

    let pw = PjrtBatchMetric::new(rt, metric_name, dim)?;
    let mr = rt
        .find_module("mreach", metric_name, dim)
        .ok_or_else(|| anyhow!("no mreach_{metric_name} module for dim {dim}"))?
        .clone_meta();
    let b = pw.block();
    let execs0 = rt.exec_count();

    // --- core distances: k-th closest neighbor (self excluded), computed
    // from B×B pairwise kernel blocks.
    let k = min_pts.min(n.saturating_sub(1)).max(1);
    let mut best: Vec<KBest> = vec![KBest::default(); n];
    let blocks: Vec<(usize, usize)> = (0..n)
        .step_by(b)
        .map(|s| (s, (s + b).min(n)))
        .collect();
    for &(xi, xe) in &blocks {
        for &(yi, ye) in &blocks {
            let block = pw.distance_block(&rows[xi..xe], &rows[yi..ye])?;
            for (i, row) in block.iter().enumerate() {
                let gi = xi + i;
                for (j, &d) in row.iter().enumerate() {
                    let gj = yi + j;
                    if gi != gj {
                        best[gi].offer(k, gj as u32, d as f64);
                    }
                }
            }
        }
    }
    let core: Vec<f32> = best.iter().map(|kb| kb.core(k) as f32).collect();
    drop(best);

    // --- Prim over the implicit mutual-reachability graph, one fused
    // mreach row (max(d, core_i, core_j), computed in-kernel) at a time.
    let mut in_tree = vec![false; n];
    let mut best_d = vec![f64::INFINITY; n];
    let mut best_from = vec![0u32; n];
    let mut edges: Vec<Edge> = Vec::with_capacity(n - 1);
    let mut current = 0usize;
    in_tree[0] = true;
    for _ in 1..n {
        let crow = [rows[current]];
        let ccore = [core[current]];
        for &(yi, ye) in &blocks {
            let mrow =
                rt.mreach(&mr.1, &crow, &rows[yi..ye], &ccore, &core[yi..ye])?;
            for (j, &d) in mrow[0].iter().enumerate() {
                let gj = yi + j;
                if !in_tree[gj] && (d as f64) < best_d[gj] {
                    best_d[gj] = d as f64;
                    best_from[gj] = current as u32;
                }
            }
        }
        // next: cheapest frontier node
        let mut next = usize::MAX;
        let mut next_d = f64::INFINITY;
        for j in 0..n {
            if !in_tree[j] && best_d[j] < next_d {
                next_d = best_d[j];
                next = j;
            }
        }
        if next == usize::MAX {
            break; // disconnected (cannot happen for finite metrics)
        }
        edges.push(Edge::new(best_from[next], next as u32, next_d));
        in_tree[next] = true;
        current = next;
    }

    Ok(PjrtExactResult {
        clustering: cluster_from_msf(&edges, n, mcs),
        kernel_execs: rt.exec_count() - execs0,
    })
}

/// (b, name) pair cloned out of a `ModuleMeta` borrow.
trait CloneMeta {
    fn clone_meta(&self) -> (usize, String);
}

impl CloneMeta for crate::runtime::ModuleMeta {
    fn clone_meta(&self) -> (usize, String) {
        (self.b, self.name.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets;
    use crate::hdbscan::exact::{exact_hdbscan, ExactParams};
    use crate::metrics::adjusted_mutual_info;
    use crate::runtime::default_artifacts_dir;

    fn runtime_or_skip() -> Option<Runtime> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.tsv").exists() {
            eprintln!("SKIP exact_pjrt tests — run `make artifacts`");
            return None;
        }
        Some(Runtime::load(&dir).expect("artifacts exist but failed to load"))
    }

    #[test]
    fn pjrt_baseline_matches_native_exact() {
        let Some(rt) = runtime_or_skip() else { return };
        let ds = datasets::blobs::generate(300, 16, 4, 9);

        let native = exact_hdbscan(
            &ds.items,
            &ds.metric,
            ExactParams { min_pts: 10, mcs: 10, matrix_budget: None },
        )
        .unwrap();
        let pjrt =
            exact_hdbscan_pjrt(&ds.items, &rt, "euclidean", 10, 10).unwrap();

        assert_eq!(
            pjrt.clustering.n_clusters,
            native.clustering.n_clusters
        );
        // f32 kernels vs f64 native: tie-breaks may differ, structure not
        let native_pred: Vec<usize> =
            native.clustering.labels.iter().map(|&l| (l + 1) as usize).collect();
        let pjrt_pred: Vec<usize> =
            pjrt.clustering.labels.iter().map(|&l| (l + 1) as usize).collect();
        let ami = adjusted_mutual_info(&pjrt_pred, &native_pred);
        assert!(ami > 0.99, "PJRT vs native AMI {ami}");
        assert!(pjrt.kernel_execs > 0);
    }

    #[test]
    fn adapter_batch_matches_scalar_and_counts_execs() {
        let Some(rt) = runtime_or_skip() else { return };
        let ds = datasets::blobs::generate(40, 16, 3, 11);
        let pw = PjrtBatchMetric::new(&rt, "euclidean", 16).unwrap();

        let q = &ds.items[0];
        let cands: Vec<&Item> = ds.items[1..].iter().collect();
        let execs0 = rt.exec_count();
        let mut batch = vec![0.0f64; cands.len()];
        pw.distance_batch(q, &cands, &mut batch);
        assert!(rt.exec_count() > execs0, "batch dispatched no kernels");

        // batch ≡ N× dist: both sides go through the same f32 kernel, so
        // the equality is exact, not a tolerance check
        for (c, &bd) in cands.iter().zip(&batch) {
            assert_eq!(pw.dist(q, c).to_bits(), bd.to_bits());
        }
    }

    #[test]
    fn owned_metric_is_trait_conformant() {
        if runtime_or_skip().is_none() {
            return;
        }
        let m = PjrtMetric::new(default_artifacts_dir(), "euclidean", 16)
            .unwrap();
        let ds = datasets::blobs::generate(20, 16, 2, 7);
        let q = &ds.items[0];
        let cands: Vec<&Item> = ds.items[1..].iter().collect();
        let mut batch = vec![0.0f64; cands.len()];
        Metric::distance_batch(&m, q, &cands, &mut batch);
        for (c, &bd) in cands.iter().zip(&batch) {
            assert_eq!(Metric::dist(&m, q, c).to_bits(), bd.to_bits());
        }
    }

    #[test]
    fn pjrt_baseline_rejects_non_dense() {
        let Some(rt) = runtime_or_skip() else { return };
        let items = vec![crate::distances::Item::Text("x".into())];
        assert!(exact_hdbscan_pjrt(&items, &rt, "euclidean", 2, 2).is_err());
    }

    #[test]
    fn pjrt_empty_input() {
        let Some(rt) = runtime_or_skip() else { return };
        let r = exact_hdbscan_pjrt(&[], &rt, "euclidean", 5, 5).unwrap();
        assert_eq!(r.clustering.n_clusters, 0);
        assert_eq!(r.kernel_execs, 0);
    }
}
