//! Flat-cluster extraction from a condensed tree via Excess-of-Mass
//! stability maximization (Campello et al. \[4\]; McInnes & Healy \[26\]).
//!
//! A cluster is selected iff its own stability exceeds the summed
//! (propagated) stability of its child clusters; the root is never
//! selected (paper, Lemma 3.3: the all-points root cluster is excluded).

use super::condense::CondensedTree;
use super::Clustering;

/// Select clusters and produce flat labels (root never selected — the
/// paper's Lemma 3.3 semantics and hdbscan's default).
pub fn extract_flat(tree: &CondensedTree) -> Clustering {
    extract_flat_opts(tree, false)
}

/// Like [`extract_flat`], but `allow_single_cluster = true` lets the root
/// compete for selection (hdbscan's `allow_single_cluster=True`): datasets
/// that are one uniform cluster then return that cluster instead of
/// all-noise.
pub fn extract_flat_opts(
    tree: &CondensedTree,
    allow_single_cluster: bool,
) -> Clustering {
    let n = tree.n_points;
    let root = tree.root();
    let k = tree.n_cluster_ids;

    // children clusters per cluster (offset ids)
    let mut child_clusters: Vec<Vec<u32>> = vec![Vec::new(); k];
    for r in &tree.rows {
        if (r.child as usize) >= n {
            child_clusters[(r.parent - root) as usize].push(r.child);
        }
    }

    let stability = tree.stabilities();
    // process ids descending (children always have larger ids than parents)
    let mut selected = vec![false; k];
    let mut propagated = stability.clone();
    for idx in (0..k).rev() {
        let kids = &child_clusters[idx];
        if idx == 0 && !allow_single_cluster {
            // root: never selected, just propagates
            continue;
        }
        if kids.is_empty() {
            selected[idx] = true; // leaf cluster: provisionally selected
            continue;
        }
        let kid_sum: f64 = kids.iter().map(|&c| propagated[(c - root) as usize]).sum();
        if stability[idx] >= kid_sum {
            selected[idx] = true;
            propagated[idx] = stability[idx];
        } else {
            propagated[idx] = kid_sum;
        }
    }

    // keep only the highest selected clusters (unselect descendants)
    let mut final_selected = vec![false; k];
    let mut stack: Vec<u32> = if allow_single_cluster {
        vec![root]
    } else {
        child_clusters[0].clone()
    };
    while let Some(c) = stack.pop() {
        let idx = (c - root) as usize;
        if selected[idx] {
            final_selected[idx] = true;
        } else {
            stack.extend(child_clusters[idx].iter().copied());
        }
    }

    // assign dense flat labels to selected clusters
    let mut label_of = vec![-1i32; k];
    let mut next = 0i32;
    for idx in 0..k {
        if final_selected[idx] {
            label_of[idx] = next;
            next += 1;
        }
    }

    // point labels: a point gets the label of the selected ancestor of the
    // cluster it falls out of (if any). Compute each cluster's nearest
    // selected ancestor top-down (ids ascend parent -> child).
    let mut sel_anc = vec![-1i32; k];
    for idx in 0..k {
        if final_selected[idx] {
            sel_anc[idx] = label_of[idx];
        }
    }
    // rows are emitted parent-before-child (BFS-ish); propagate via rows
    // ordered by child id ascending to be safe
    let mut cluster_rows: Vec<(u32, u32)> = tree
        .rows
        .iter()
        .filter(|r| (r.child as usize) >= n)
        .map(|r| (r.parent, r.child))
        .collect();
    cluster_rows.sort_unstable_by_key(|&(_, c)| c);
    for (p, c) in cluster_rows {
        let (pi, ci) = ((p - root) as usize, (c - root) as usize);
        if sel_anc[ci] < 0 {
            sel_anc[ci] = sel_anc[pi];
        }
    }

    let mut labels = vec![-1i32; n];
    for r in &tree.rows {
        if (r.child as usize) < n {
            labels[r.child as usize] = sel_anc[(r.parent - root) as usize];
        }
    }

    Clustering {
        labels,
        n_clusters: next as usize,
        condensed: tree.clone(),
        selected: (0..k)
            .filter(|&i| final_selected[i])
            .map(|i| root + i as u32)
            .collect(),
    }
}

/// Leaf extraction: select every *leaf* of the condensed tree instead of
/// maximizing stability — yields the finest-grained clustering the
/// hierarchy supports (hdbscan's `cluster_selection_method="leaf"`).
/// Useful when EoM collapses interesting sub-structure into one big
/// cluster (the flip side of the paper's "fewer larger clusters"
/// regularization observation).
pub fn extract_leaf(tree: &CondensedTree) -> Clustering {
    let n = tree.n_points;
    let root = tree.root();
    let k = tree.n_cluster_ids;

    let mut has_child_cluster = vec![false; k];
    for r in &tree.rows {
        if (r.child as usize) >= n {
            has_child_cluster[(r.parent - root) as usize] = true;
        }
    }
    // leaves, root excluded (and excluding the degenerate single-cluster
    // case where the root is the only node)
    let mut label_of = vec![-1i32; k];
    let mut next = 0i32;
    for idx in 1..k {
        if !has_child_cluster[idx] {
            label_of[idx] = next;
            next += 1;
        }
    }
    let mut labels = vec![-1i32; n];
    for r in &tree.rows {
        if (r.child as usize) < n {
            labels[r.child as usize] = label_of[(r.parent - root) as usize];
        }
    }
    Clustering {
        labels,
        n_clusters: next as usize,
        condensed: tree.clone(),
        selected: (1..k)
            .filter(|&i| label_of[i] >= 0)
            .map(|i| root + i as u32)
            .collect(),
    }
}

/// DBSCAN\*-style flat cut: connected components of the MSF restricted to
/// edges with weight ≤ `eps`, keeping components with at least `min_size`
/// points (everything else is noise). This is HDBSCAN\* with a single
/// global density threshold — exactly the ε the paper says HDBSCAN\*
/// removes ("tuned automatically and separately for each cluster", §2) —
/// provided for exploration and for DBSCAN-comparison experiments.
///
/// Only **finite** edge weights can union: a `+∞` weight means "mutual
/// reachability unknown" (a core distance never resolved, or a hostile
/// metric's `NaN`/`-inf` sanitized at the HNSW choke point), not "within
/// every ε". Without the guard, `eps = f64::INFINITY` — the natural "cut
/// nothing" probe — would glue all components through those sentinel
/// edges. A `NaN` eps fails every comparison and cuts everything, by the
/// same IEEE rules.
pub fn cut_at_distance(
    edges: &[crate::mst::Edge],
    n_points: usize,
    eps: f64,
    min_size: usize,
) -> Vec<i32> {
    let mut uf = crate::mst::UnionFind::new(n_points);
    for e in edges {
        if e.w.is_finite() && e.w <= eps {
            uf.union(e.a, e.b);
        }
    }
    let mut count = std::collections::HashMap::new();
    for i in 0..n_points as u32 {
        *count.entry(uf.find(i)).or_insert(0usize) += 1;
    }
    let mut label_of = std::collections::HashMap::new();
    let mut next = 0i32;
    let mut labels = vec![-1i32; n_points];
    for i in 0..n_points as u32 {
        let r = uf.find(i);
        if count[&r] >= min_size.max(1) {
            let l = *label_of.entry(r).or_insert_with(|| {
                let l = next;
                next += 1;
                l
            });
            labels[i as usize] = l;
        }
    }
    labels
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdbscan::condense::{CondensedTree, Dendrogram};
    use crate::mst::Edge;
    use crate::util::proptest::check;

    fn cluster(edges: &[Edge], n: usize, mcs: usize) -> Clustering {
        let d = Dendrogram::from_msf(edges, n);
        let t = CondensedTree::from_dendrogram(&d, mcs);
        extract_flat(&t)
    }

    #[test]
    fn nested_clusters_prefer_children_when_tighter() {
        // two tight blobs (intra 0.1) inside a loose super-cluster (bridge
        // 1.0), isolated from a far singleton cloud (bridge 100).
        let mut edges = Vec::new();
        for i in 0..4u32 {
            edges.push(Edge::new(i, i + 1, 0.1)); // blob A: 0-4
            edges.push(Edge::new(5 + i, 6 + i, 0.1)); // blob B: 5-9
        }
        edges.push(Edge::new(4, 5, 1.0)); // A-B bridge
        for i in 10..14u32 {
            edges.push(Edge::new(i, i + 1, 100.0)); // sparse cloud 10-14
        }
        edges.push(Edge::new(9, 10, 500.0));
        let c = cluster(&edges, 15, 3);
        // the two tight blobs must be separate clusters
        assert!(c.n_clusters >= 2, "clusters: {} labels {:?}", c.n_clusters, c.labels);
        assert_eq!(c.labels[0], c.labels[4]);
        assert_eq!(c.labels[5], c.labels[9]);
        assert_ne!(c.labels[0], c.labels[5]);
    }

    #[test]
    fn root_never_selected() {
        // homogeneous chain: root would be the only candidate; selection
        // must instead pick its child clusters (or everything is noise)
        let edges: Vec<Edge> =
            (0..19u32).map(|i| Edge::new(i, i + 1, 1.0)).collect();
        let c = cluster(&edges, 20, 3);
        for &s in &c.selected {
            assert_ne!(s, c.condensed.root());
        }
    }

    #[test]
    fn labels_dense_and_consistent_with_sizes() {
        let mut edges = Vec::new();
        for i in 0..9u32 {
            edges.push(Edge::new(i, i + 1, 1.0));
            edges.push(Edge::new(20 + i, 21 + i, 1.0));
        }
        edges.push(Edge::new(9, 20, 30.0));
        let c = cluster(&edges, 30, 4);
        let sizes = c.cluster_sizes();
        assert_eq!(sizes.len(), c.n_clusters);
        assert_eq!(sizes.iter().sum::<usize>(), c.n_clustered());
        for (i, &s) in sizes.iter().enumerate() {
            assert!(s > 0, "empty flat cluster {i}");
        }
    }

    #[test]
    fn leaf_extraction_is_at_least_as_fine_as_eom() {
        // nested structure: EoM may pick the parents; leaf must pick leaves
        let mut edges = Vec::new();
        for i in 0..4u32 {
            edges.push(Edge::new(i, i + 1, 0.1)); // tight blob A
            edges.push(Edge::new(5 + i, 6 + i, 0.1)); // tight blob B
            edges.push(Edge::new(10 + i, 11 + i, 0.1)); // tight blob C
        }
        edges.push(Edge::new(4, 5, 2.0));
        edges.push(Edge::new(9, 10, 2.0));
        let d = Dendrogram::from_msf(&edges, 15);
        let t = CondensedTree::from_dendrogram(&d, 3);
        let eom = extract_flat(&t);
        let leaf = extract_leaf(&t);
        assert!(leaf.n_clusters >= eom.n_clusters);
        // every leaf-selected cluster has no child cluster in the tree
        for &s in &leaf.selected {
            assert!(
                !t.rows.iter().any(|r| r.parent == s && (r.child as usize) >= 15),
                "leaf selection picked an internal cluster"
            );
        }
    }

    #[test]
    fn cut_at_distance_matches_component_structure() {
        // chain 0-4 (w=1), chain 5-9 (w=1), bridge w=10
        let mut edges = Vec::new();
        for i in 0..4u32 {
            edges.push(Edge::new(i, i + 1, 1.0));
            edges.push(Edge::new(5 + i, 6 + i, 1.0));
        }
        edges.push(Edge::new(4, 5, 10.0));
        // eps below the bridge: two clusters
        let l = cut_at_distance(&edges, 10, 2.0, 2);
        assert_eq!(l.iter().collect::<std::collections::HashSet<_>>().len(), 2);
        assert_eq!(l[0], l[4]);
        assert_ne!(l[0], l[5]);
        // eps above the bridge: one cluster
        let l = cut_at_distance(&edges, 10, 20.0, 2);
        assert!(l.iter().all(|&x| x == 0));
        // min_size filters: singletons become noise
        let l = cut_at_distance(&edges, 10, 0.5, 2);
        assert!(l.iter().all(|&x| x == -1), "no edge ≤ 0.5 ⇒ all noise");
    }

    /// Regression (ISSUE 5 satellite): `+∞` sentinel weights — hostile
    /// metrics sanitized at the HNSW choke point, or cores that never
    /// resolved — must not glue components when callers probe with
    /// `eps = f64::INFINITY`.
    #[test]
    fn cut_ignores_non_finite_weights_and_eps() {
        // two finite chains joined only by a +inf sentinel edge
        let mut edges = Vec::new();
        for i in 0..4u32 {
            edges.push(Edge::new(i, i + 1, 1.0));
            edges.push(Edge::new(5 + i, 6 + i, 1.0));
        }
        edges.push(Edge::new(4, 5, f64::INFINITY));

        // eps = +inf ("cut nothing"): the sentinel must still not union
        let l = cut_at_distance(&edges, 10, f64::INFINITY, 2);
        assert_eq!(
            l.iter().collect::<std::collections::HashSet<_>>().len(),
            2,
            "infinite-weight edge glued the components: {l:?}"
        );
        assert_eq!(l[0], l[4]);
        assert_ne!(l[0], l[5]);

        // finite eps behaves as before
        let l = cut_at_distance(&edges, 10, 2.0, 2);
        assert_eq!(l[0], l[4]);
        assert_ne!(l[0], l[5]);

        // NaN eps: every comparison fails, everything is noise — never a
        // panic, never a glue
        let l = cut_at_distance(&edges, 10, f64::NAN, 2);
        assert!(l.iter().all(|&x| x == -1), "NaN eps must cut everything");
    }

    #[test]
    fn prop_cut_monotone_in_eps() {
        check("cut-monotone", 20, |rng, _| {
            let n = 5 + rng.below(60);
            let mut edges = Vec::new();
            for i in 1..n as u32 {
                let parent = rng.below(i as usize) as u32;
                edges.push(Edge::new(parent, i, rng.f64() * 4.0));
            }
            let l1 = cut_at_distance(&edges, n, 1.0, 2);
            let l2 = cut_at_distance(&edges, n, 2.0, 2);
            // clusters can only merge as eps grows: same-cluster pairs at
            // eps=1 stay together at eps=2
            for i in 0..n {
                for j in (i + 1)..n {
                    if l1[i] >= 0 && l1[i] == l1[j] {
                        assert!(
                            l2[i] >= 0 && l2[i] == l2[j],
                            "pair ({i},{j}) split when eps grew"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn prop_extraction_invariants() {
        check("extract-invariants", 30, |rng, _| {
            let n = 6 + rng.below(100);
            let mut edges = Vec::new();
            for i in 1..n as u32 {
                let parent = rng.below(i as usize) as u32;
                edges.push(Edge::new(parent, i, rng.f64() * 5.0 + 0.01));
            }
            let mcs = 2 + rng.below(6);
            let c = cluster(&edges, n, mcs);

            // labels in range
            assert!(c.labels.iter().all(|&l| l >= -1 && (l as i64) < c.n_clusters as i64));
            // every flat cluster has >= mcs points? Not guaranteed by EOM
            // (leaf clusters have >= mcs by construction of the condensed
            // tree, and selected clusters are condensed clusters) — check:
            let sizes = c.cluster_sizes();
            for &s in &sizes {
                assert!(s >= 1);
            }
            // selected clusters are disjoint: total clustered <= n
            assert!(c.n_clustered() <= n);
            // hierarchical counts are supersets of flat
            assert!(c.n_hierarchical_clustered() <= n);
            assert!(c.n_hierarchical_clusters() + 1 >= c.n_clusters);
        });
    }
}
