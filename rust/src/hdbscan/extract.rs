//! Flat-cluster extraction from a condensed tree via Excess-of-Mass
//! stability maximization (Campello et al. \[4\]; McInnes & Healy \[26\]).
//!
//! A cluster is selected iff its own stability exceeds the summed
//! (propagated) stability of its child clusters; the root is never
//! selected (paper, Lemma 3.3: the all-points root cluster is excluded).

use super::condense::CondensedTree;
use super::Clustering;

/// How a flat clustering is selected from the condensed hierarchy. The
/// paper's "H" axis: one cached hierarchy serves every granularity, so
/// the selection policy is a runtime parameter of extraction, not a
/// build-time choice of the tree.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum ExtractionMode {
    /// Excess-of-Mass stability maximization (Campello et al. \[4\]; the
    /// HDBSCAN\* default, [`extract_flat`]).
    #[default]
    Stability,
    /// Every leaf of the condensed tree ([`extract_leaf`]): the finest
    /// granularity the hierarchy supports.
    Leaf,
    /// Malzer & Baum's hybrid eps+stability selection (HDBSCAN(ε̂),
    /// arxiv 1911.02282; [`extract_hybrid`]): EoM selection first, then
    /// every selected cluster born below the eps threshold climbs to the
    /// first ancestor born above it.
    HybridEps,
}

impl ExtractionMode {
    /// Stable lowercase name (journal events, stats JSON, CLI tables).
    pub fn name(&self) -> &'static str {
        match self {
            ExtractionMode::Stability => "stability",
            ExtractionMode::Leaf => "leaf",
            ExtractionMode::HybridEps => "hybrid_eps",
        }
    }

    /// Inverse of [`ExtractionMode::name`] (plus common aliases).
    pub fn parse(s: &str) -> Option<ExtractionMode> {
        match s {
            "stability" | "eom" => Some(ExtractionMode::Stability),
            "leaf" => Some(ExtractionMode::Leaf),
            "hybrid_eps" | "hybrid" => Some(ExtractionMode::HybridEps),
            _ => None,
        }
    }
}

/// Child *cluster* lists per cluster (indexed by offset id `id - root`).
fn child_cluster_lists(tree: &CondensedTree) -> Vec<Vec<u32>> {
    let n = tree.n_points;
    let root = tree.root();
    let mut kids: Vec<Vec<u32>> = vec![Vec::new(); tree.n_cluster_ids];
    for r in &tree.rows {
        if (r.child as usize) >= n {
            kids[(r.parent - root) as usize].push(r.child);
        }
    }
    kids
}

/// The EoM selection bitmap with descendants of selected clusters
/// unselected (only the highest selected clusters survive) — the shared
/// front half of [`extract_flat_opts`] and [`extract_hybrid`].
fn eom_final_selection(
    tree: &CondensedTree,
    allow_single_cluster: bool,
    kids: &[Vec<u32>],
) -> Vec<bool> {
    let root = tree.root();
    let k = tree.n_cluster_ids;
    let stability = tree.stabilities();
    // process ids descending (children always have larger ids than parents)
    let mut selected = vec![false; k];
    let mut propagated = stability.clone();
    for idx in (0..k).rev() {
        let ks = &kids[idx];
        if idx == 0 && !allow_single_cluster {
            // root: never selected, just propagates
            continue;
        }
        if ks.is_empty() {
            selected[idx] = true; // leaf cluster: provisionally selected
            continue;
        }
        let kid_sum: f64 =
            ks.iter().map(|&c| propagated[(c - root) as usize]).sum();
        if stability[idx] >= kid_sum {
            selected[idx] = true;
            propagated[idx] = stability[idx];
        } else {
            propagated[idx] = kid_sum;
        }
    }

    // keep only the highest selected clusters (unselect descendants)
    let mut final_selected = vec![false; k];
    let mut stack: Vec<u32> = if allow_single_cluster {
        vec![root]
    } else {
        kids[0].clone()
    };
    while let Some(c) = stack.pop() {
        let idx = (c - root) as usize;
        if selected[idx] {
            final_selected[idx] = true;
        } else {
            stack.extend(kids[idx].iter().copied());
        }
    }
    final_selected
}

/// Turn a selection bitmap into the flat [`Clustering`]: dense labels in
/// ascending cluster-id order, each point labeled by the *innermost*
/// selected ancestor of the cluster it fell out of (nesting only arises
/// in the hybrid mode; for an antichain selection this is simply "the
/// selected ancestor"). Shared by every extraction mode so the label
/// assignment semantics cannot drift between them.
fn clustering_from_selection(
    tree: &CondensedTree,
    final_selected: &[bool],
) -> Clustering {
    let n = tree.n_points;
    let root = tree.root();
    let k = tree.n_cluster_ids;

    // assign dense flat labels to selected clusters
    let mut label_of = vec![-1i32; k];
    let mut next = 0i32;
    for idx in 0..k {
        if final_selected[idx] {
            label_of[idx] = next;
            next += 1;
        }
    }

    // point labels: a point gets the label of the selected ancestor of the
    // cluster it falls out of (if any). Compute each cluster's nearest
    // selected ancestor top-down (ids ascend parent -> child).
    let mut sel_anc = vec![-1i32; k];
    for idx in 0..k {
        if final_selected[idx] {
            sel_anc[idx] = label_of[idx];
        }
    }
    // rows are emitted parent-before-child (BFS-ish); propagate via rows
    // ordered by child id ascending to be safe
    let mut cluster_rows: Vec<(u32, u32)> = tree
        .rows
        .iter()
        .filter(|r| (r.child as usize) >= n)
        .map(|r| (r.parent, r.child))
        .collect();
    cluster_rows.sort_unstable_by_key(|&(_, c)| c);
    for (p, c) in cluster_rows {
        let (pi, ci) = ((p - root) as usize, (c - root) as usize);
        if sel_anc[ci] < 0 {
            sel_anc[ci] = sel_anc[pi];
        }
    }

    let mut labels = vec![-1i32; n];
    for r in &tree.rows {
        if (r.child as usize) < n {
            labels[r.child as usize] = sel_anc[(r.parent - root) as usize];
        }
    }

    Clustering {
        labels,
        n_clusters: next as usize,
        condensed: tree.clone(),
        selected: (0..k)
            .filter(|&i| final_selected[i])
            .map(|i| root + i as u32)
            .collect(),
    }
}

/// Select clusters and produce flat labels (root never selected — the
/// paper's Lemma 3.3 semantics and hdbscan's default).
pub fn extract_flat(tree: &CondensedTree) -> Clustering {
    extract_flat_opts(tree, false)
}

/// Like [`extract_flat`], but `allow_single_cluster = true` lets the root
/// compete for selection (hdbscan's `allow_single_cluster=True`): datasets
/// that are one uniform cluster then return that cluster instead of
/// all-noise.
pub fn extract_flat_opts(
    tree: &CondensedTree,
    allow_single_cluster: bool,
) -> Clustering {
    let kids = child_cluster_lists(tree);
    let final_selected = eom_final_selection(tree, allow_single_cluster, &kids);
    clustering_from_selection(tree, &final_selected)
}

/// Malzer & Baum's hybrid eps+stability extraction (HDBSCAN(ε̂), arxiv
/// 1911.02282): run EoM stability selection, then let every selected
/// cluster *born below the eps threshold* (birth distance
/// `1 / birth_lambda < eps`) climb to the first ancestor born above the
/// threshold. The effect is a DBSCAN\*-style minimum granularity — micro
/// clusters that only exist below `eps` are merged — while clusters
/// already coarser than `eps` keep their EoM selection untouched.
///
/// Two boundary contracts (unit-tested):
/// - `eps <= 0` (or `NaN`) imposes no threshold and must reduce
///   **bit-identically** to [`extract_flat_opts`].
/// - `eps = +inf` must honor the same finite-weight guard as
///   [`cut_at_distance`]: clusters created by the forest's virtual `+∞`
///   merges are born at `lambda = 0`, i.e. at birth distance `+∞`, and
///   `∞ < ∞` is false — so no climb ever crosses a sanitized `+∞`
///   sentinel boundary and disconnected components are never glued.
pub fn extract_hybrid(
    tree: &CondensedTree,
    eps: f64,
    allow_single_cluster: bool,
) -> Clustering {
    if !(eps > 0.0) {
        // no threshold: pure stability selection, bit-identical
        return extract_flat_opts(tree, allow_single_cluster);
    }
    let n = tree.n_points;
    let root = tree.root();
    let k = tree.n_cluster_ids;
    let kids = child_cluster_lists(tree);
    let eom = eom_final_selection(tree, allow_single_cluster, &kids);

    // birth distance per cluster: 1 / birth_lambda, with lambda = 0 (the
    // root and any cluster created by a virtual +inf merge) mapping to
    // +inf — never `< eps`, so sentinel boundaries stop every climb.
    let birth_eps: Vec<f64> = tree
        .birth_lambdas()
        .iter()
        .map(|&l| if l > 0.0 { 1.0 / l } else { f64::INFINITY })
        .collect();

    let mut parent_of: Vec<u32> = vec![root; k];
    for r in &tree.rows {
        if (r.child as usize) >= n {
            parent_of[(r.child - root) as usize] = r.parent;
        }
    }

    let mut final_selected = vec![false; k];
    // clusters already covered by a climbed-to ancestor (hdbscan's
    // `processed` set): skip their own climbs
    let mut covered = vec![false; k];
    for idx in 0..k {
        if !eom[idx] {
            continue;
        }
        if !(birth_eps[idx] < eps) {
            // born at or above the threshold: keep the EoM choice
            final_selected[idx] = true;
            continue;
        }
        if covered[idx] {
            continue;
        }
        // climb to the first ancestor born above the threshold
        // (hdbscan's traverse_upwards: the root check comes first; when
        // the parent is the root, keep the highest non-root node — or the
        // root itself iff a single cluster is allowed)
        let mut at = idx;
        loop {
            let parent = parent_of[at];
            if parent == root {
                if allow_single_cluster {
                    at = 0;
                }
                break;
            }
            let pi = (parent - root) as usize;
            at = pi;
            if birth_eps[pi] > eps {
                break;
            }
        }
        final_selected[at] = true;
        // everything inside the chosen ancestor is covered by it
        let mut stack = kids[at].clone();
        while let Some(c) = stack.pop() {
            let ci = (c - root) as usize;
            covered[ci] = true;
            stack.extend(kids[ci].iter().copied());
        }
    }
    clustering_from_selection(tree, &final_selected)
}

/// Leaf extraction: select every *leaf* of the condensed tree instead of
/// maximizing stability — yields the finest-grained clustering the
/// hierarchy supports (hdbscan's `cluster_selection_method="leaf"`).
/// Useful when EoM collapses interesting sub-structure into one big
/// cluster (the flip side of the paper's "fewer larger clusters"
/// regularization observation).
pub fn extract_leaf(tree: &CondensedTree) -> Clustering {
    let k = tree.n_cluster_ids;
    let kids = child_cluster_lists(tree);
    // leaves, root excluded (and excluding the degenerate single-cluster
    // case where the root is the only node)
    let mut final_selected = vec![false; k];
    for idx in 1..k {
        final_selected[idx] = kids[idx].is_empty();
    }
    clustering_from_selection(tree, &final_selected)
}

/// DBSCAN\*-style flat cut: connected components of the MSF restricted to
/// edges with weight ≤ `eps`, keeping components with at least `min_size`
/// points (everything else is noise). This is HDBSCAN\* with a single
/// global density threshold — exactly the ε the paper says HDBSCAN\*
/// removes ("tuned automatically and separately for each cluster", §2) —
/// provided for exploration and for DBSCAN-comparison experiments.
///
/// Only **finite** edge weights can union: a `+∞` weight means "mutual
/// reachability unknown" (a core distance never resolved, or a hostile
/// metric's `NaN`/`-inf` sanitized at the HNSW choke point), not "within
/// every ε". Without the guard, `eps = f64::INFINITY` — the natural "cut
/// nothing" probe — would glue all components through those sentinel
/// edges. A `NaN` eps fails every comparison and cuts everything, by the
/// same IEEE rules.
pub fn cut_at_distance(
    edges: &[crate::mst::Edge],
    n_points: usize,
    eps: f64,
    min_size: usize,
) -> Vec<i32> {
    let mut uf = crate::mst::UnionFind::new(n_points);
    for e in edges {
        if e.w.is_finite() && e.w <= eps {
            uf.union(e.a, e.b);
        }
    }
    let mut count = std::collections::HashMap::new();
    for i in 0..n_points as u32 {
        *count.entry(uf.find(i)).or_insert(0usize) += 1;
    }
    let mut label_of = std::collections::HashMap::new();
    let mut next = 0i32;
    let mut labels = vec![-1i32; n_points];
    for i in 0..n_points as u32 {
        let r = uf.find(i);
        if count[&r] >= min_size.max(1) {
            let l = *label_of.entry(r).or_insert_with(|| {
                let l = next;
                next += 1;
                l
            });
            labels[i as usize] = l;
        }
    }
    labels
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdbscan::condense::{CondensedTree, Dendrogram};
    use crate::mst::Edge;
    use crate::util::proptest::check;

    fn cluster(edges: &[Edge], n: usize, mcs: usize) -> Clustering {
        let d = Dendrogram::from_msf(edges, n);
        let t = CondensedTree::from_dendrogram(&d, mcs);
        extract_flat(&t)
    }

    #[test]
    fn nested_clusters_prefer_children_when_tighter() {
        // two tight blobs (intra 0.1) inside a loose super-cluster (bridge
        // 1.0), isolated from a far singleton cloud (bridge 100).
        let mut edges = Vec::new();
        for i in 0..4u32 {
            edges.push(Edge::new(i, i + 1, 0.1)); // blob A: 0-4
            edges.push(Edge::new(5 + i, 6 + i, 0.1)); // blob B: 5-9
        }
        edges.push(Edge::new(4, 5, 1.0)); // A-B bridge
        for i in 10..14u32 {
            edges.push(Edge::new(i, i + 1, 100.0)); // sparse cloud 10-14
        }
        edges.push(Edge::new(9, 10, 500.0));
        let c = cluster(&edges, 15, 3);
        // the two tight blobs must be separate clusters
        assert!(c.n_clusters >= 2, "clusters: {} labels {:?}", c.n_clusters, c.labels);
        assert_eq!(c.labels[0], c.labels[4]);
        assert_eq!(c.labels[5], c.labels[9]);
        assert_ne!(c.labels[0], c.labels[5]);
    }

    #[test]
    fn root_never_selected() {
        // homogeneous chain: root would be the only candidate; selection
        // must instead pick its child clusters (or everything is noise)
        let edges: Vec<Edge> =
            (0..19u32).map(|i| Edge::new(i, i + 1, 1.0)).collect();
        let c = cluster(&edges, 20, 3);
        for &s in &c.selected {
            assert_ne!(s, c.condensed.root());
        }
    }

    #[test]
    fn labels_dense_and_consistent_with_sizes() {
        let mut edges = Vec::new();
        for i in 0..9u32 {
            edges.push(Edge::new(i, i + 1, 1.0));
            edges.push(Edge::new(20 + i, 21 + i, 1.0));
        }
        edges.push(Edge::new(9, 20, 30.0));
        let c = cluster(&edges, 30, 4);
        let sizes = c.cluster_sizes();
        assert_eq!(sizes.len(), c.n_clusters);
        assert_eq!(sizes.iter().sum::<usize>(), c.n_clustered());
        for (i, &s) in sizes.iter().enumerate() {
            assert!(s > 0, "empty flat cluster {i}");
        }
    }

    #[test]
    fn leaf_extraction_is_at_least_as_fine_as_eom() {
        // nested structure: EoM may pick the parents; leaf must pick leaves
        let mut edges = Vec::new();
        for i in 0..4u32 {
            edges.push(Edge::new(i, i + 1, 0.1)); // tight blob A
            edges.push(Edge::new(5 + i, 6 + i, 0.1)); // tight blob B
            edges.push(Edge::new(10 + i, 11 + i, 0.1)); // tight blob C
        }
        edges.push(Edge::new(4, 5, 2.0));
        edges.push(Edge::new(9, 10, 2.0));
        let d = Dendrogram::from_msf(&edges, 15);
        let t = CondensedTree::from_dendrogram(&d, 3);
        let eom = extract_flat(&t);
        let leaf = extract_leaf(&t);
        assert!(leaf.n_clusters >= eom.n_clusters);
        // every leaf-selected cluster has no child cluster in the tree
        for &s in &leaf.selected {
            assert!(
                !t.rows.iter().any(|r| r.parent == s && (r.child as usize) >= 15),
                "leaf selection picked an internal cluster"
            );
        }
    }

    #[test]
    fn cut_at_distance_matches_component_structure() {
        // chain 0-4 (w=1), chain 5-9 (w=1), bridge w=10
        let mut edges = Vec::new();
        for i in 0..4u32 {
            edges.push(Edge::new(i, i + 1, 1.0));
            edges.push(Edge::new(5 + i, 6 + i, 1.0));
        }
        edges.push(Edge::new(4, 5, 10.0));
        // eps below the bridge: two clusters
        let l = cut_at_distance(&edges, 10, 2.0, 2);
        assert_eq!(l.iter().collect::<std::collections::HashSet<_>>().len(), 2);
        assert_eq!(l[0], l[4]);
        assert_ne!(l[0], l[5]);
        // eps above the bridge: one cluster
        let l = cut_at_distance(&edges, 10, 20.0, 2);
        assert!(l.iter().all(|&x| x == 0));
        // min_size filters: singletons become noise
        let l = cut_at_distance(&edges, 10, 0.5, 2);
        assert!(l.iter().all(|&x| x == -1), "no edge ≤ 0.5 ⇒ all noise");
    }

    /// Regression (ISSUE 5 satellite): `+∞` sentinel weights — hostile
    /// metrics sanitized at the HNSW choke point, or cores that never
    /// resolved — must not glue components when callers probe with
    /// `eps = f64::INFINITY`.
    #[test]
    fn cut_ignores_non_finite_weights_and_eps() {
        // two finite chains joined only by a +inf sentinel edge
        let mut edges = Vec::new();
        for i in 0..4u32 {
            edges.push(Edge::new(i, i + 1, 1.0));
            edges.push(Edge::new(5 + i, 6 + i, 1.0));
        }
        edges.push(Edge::new(4, 5, f64::INFINITY));

        // eps = +inf ("cut nothing"): the sentinel must still not union
        let l = cut_at_distance(&edges, 10, f64::INFINITY, 2);
        assert_eq!(
            l.iter().collect::<std::collections::HashSet<_>>().len(),
            2,
            "infinite-weight edge glued the components: {l:?}"
        );
        assert_eq!(l[0], l[4]);
        assert_ne!(l[0], l[5]);

        // finite eps behaves as before
        let l = cut_at_distance(&edges, 10, 2.0, 2);
        assert_eq!(l[0], l[4]);
        assert_ne!(l[0], l[5]);

        // NaN eps: every comparison fails, everything is noise — never a
        // panic, never a glue
        let l = cut_at_distance(&edges, 10, f64::NAN, 2);
        assert!(l.iter().all(|&x| x == -1), "NaN eps must cut everything");
    }

    #[test]
    fn prop_cut_monotone_in_eps() {
        check("cut-monotone", 20, |rng, _| {
            let n = 5 + rng.below(60);
            let mut edges = Vec::new();
            for i in 1..n as u32 {
                let parent = rng.below(i as usize) as u32;
                edges.push(Edge::new(parent, i, rng.f64() * 4.0));
            }
            let l1 = cut_at_distance(&edges, n, 1.0, 2);
            let l2 = cut_at_distance(&edges, n, 2.0, 2);
            // clusters can only merge as eps grows: same-cluster pairs at
            // eps=1 stay together at eps=2
            for i in 0..n {
                for j in (i + 1)..n {
                    if l1[i] >= 0 && l1[i] == l1[j] {
                        assert!(
                            l2[i] >= 0 && l2[i] == l2[j],
                            "pair ({i},{j}) split when eps grew"
                        );
                    }
                }
            }
        });
    }

    /// Satellite bugfix contract: `eps = 0` (and `NaN`) impose no
    /// threshold and must reduce **bit-identically** to pure stability
    /// selection — same labels, same cluster count, same selected ids.
    #[test]
    fn prop_hybrid_eps_zero_is_bitwise_stability() {
        check("hybrid-eps-zero", 30, |rng, _| {
            let n = 6 + rng.below(100);
            let mut edges = Vec::new();
            for i in 1..n as u32 {
                let parent = rng.below(i as usize) as u32;
                edges.push(Edge::new(parent, i, rng.f64() * 5.0 + 0.01));
            }
            let mcs = 2 + rng.below(6);
            let d = Dendrogram::from_msf(&edges, n);
            let t = CondensedTree::from_dendrogram(&d, mcs);
            for allow_single in [false, true] {
                let eom = extract_flat_opts(&t, allow_single);
                for eps in [0.0, -1.0, f64::NAN] {
                    let h = extract_hybrid(&t, eps, allow_single);
                    assert_eq!(h.labels, eom.labels, "eps={eps}");
                    assert_eq!(h.n_clusters, eom.n_clusters, "eps={eps}");
                    assert_eq!(h.selected, eom.selected, "eps={eps}");
                }
            }
        });
    }

    /// Satellite bugfix contract: `eps = +inf` must honor the same
    /// finite-weight guard as `cut_at_distance` — components joined only
    /// through sanitized `+∞` sentinel edges (forest virtual merges) are
    /// born at birth distance `+∞` and must never be glued, even by the
    /// "merge everything" probe.
    #[test]
    fn hybrid_eps_inf_respects_infinite_sentinels() {
        // two finite chains joined only by a +inf sentinel edge: the MSF
        // is a forest at every finite density level
        let mut edges = Vec::new();
        for i in 0..7u32 {
            edges.push(Edge::new(i, i + 1, 1.0)); // component A: 0-7
            edges.push(Edge::new(8 + i, 9 + i, 1.0)); // component B: 8-15
        }
        edges.push(Edge::new(7, 8, f64::INFINITY));
        let d = Dendrogram::from_msf(&edges, 16);
        let t = CondensedTree::from_dendrogram(&d, 3);
        let h = extract_hybrid(&t, f64::INFINITY, false);
        // eps=+inf merges everything *within* a component, but must not
        // cross the sentinel: A and B stay distinct clusters
        assert!(
            h.labels[..8].iter().all(|&l| l >= 0 && l == h.labels[0]),
            "component A fragmented: {:?}",
            h.labels
        );
        assert!(
            h.labels[8..].iter().all(|&l| l >= 0 && l == h.labels[8]),
            "component B fragmented: {:?}",
            h.labels
        );
        assert_ne!(
            h.labels[0], h.labels[8],
            "+inf eps glued across the sentinel edge"
        );
    }

    #[test]
    fn hybrid_merges_clusters_born_below_threshold() {
        // tight blobs A (0-4) and B (5-9) bridged at 2.0, far cloud C
        // (10-14) bridged at 50: A and B are born at distance 2.0 when
        // their super-cluster splits; C and A∪B are born at 50.
        let mut edges = Vec::new();
        for i in 0..4u32 {
            edges.push(Edge::new(i, i + 1, 0.1));
            edges.push(Edge::new(5 + i, 6 + i, 0.1));
            edges.push(Edge::new(10 + i, 11 + i, 0.1));
        }
        edges.push(Edge::new(4, 5, 2.0));
        edges.push(Edge::new(9, 10, 50.0));
        let d = Dendrogram::from_msf(&edges, 15);
        let t = CondensedTree::from_dendrogram(&d, 3);

        // below the A/B birth distance: EoM untouched — A, B, C distinct
        let fine = extract_hybrid(&t, 1.0, false);
        assert_eq!(fine.labels, extract_flat(&t).labels);
        assert_ne!(fine.labels[0], fine.labels[5]);

        // above it (but below 50): A and B glue into their super-cluster,
        // C keeps its own label
        let coarse = extract_hybrid(&t, 5.0, false);
        assert_eq!(coarse.labels[0], coarse.labels[9], "A+B not merged");
        assert!(coarse.labels[10] >= 0);
        assert_ne!(coarse.labels[0], coarse.labels[10], "C glued at eps=5");
    }

    /// Hybrid labels stay structurally valid across random forests and
    /// eps values: in range, and never splitting a cluster the pure EoM
    /// selection kept whole (climbing can only coarsen).
    #[test]
    fn prop_hybrid_only_coarsens_eom() {
        check("hybrid-coarsens", 25, |rng, _| {
            let n = 6 + rng.below(80);
            let mut edges = Vec::new();
            for i in 1..n as u32 {
                let parent = rng.below(i as usize) as u32;
                edges.push(Edge::new(parent, i, rng.f64() * 5.0 + 0.01));
            }
            let mcs = 2 + rng.below(5);
            let d = Dendrogram::from_msf(&edges, n);
            let t = CondensedTree::from_dendrogram(&d, mcs);
            let eom = extract_flat(&t);
            let eps = rng.f64() * 8.0;
            let h = extract_hybrid(&t, eps, false);
            assert!(h
                .labels
                .iter()
                .all(|&l| l >= -1 && (l as i64) < h.n_clusters as i64));
            for i in 0..n {
                for j in (i + 1)..n {
                    if eom.labels[i] >= 0 && eom.labels[i] == eom.labels[j] {
                        assert!(
                            h.labels[i] == h.labels[j],
                            "hybrid(eps={eps}) split an EoM cluster at ({i},{j})"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn extraction_mode_names_round_trip() {
        for m in [
            ExtractionMode::Stability,
            ExtractionMode::Leaf,
            ExtractionMode::HybridEps,
        ] {
            assert_eq!(ExtractionMode::parse(m.name()), Some(m));
        }
        assert_eq!(ExtractionMode::parse("eom"), Some(ExtractionMode::Stability));
        assert_eq!(ExtractionMode::parse("nope"), None);
    }

    #[test]
    fn prop_extraction_invariants() {
        check("extract-invariants", 30, |rng, _| {
            let n = 6 + rng.below(100);
            let mut edges = Vec::new();
            for i in 1..n as u32 {
                let parent = rng.below(i as usize) as u32;
                edges.push(Edge::new(parent, i, rng.f64() * 5.0 + 0.01));
            }
            let mcs = 2 + rng.below(6);
            let c = cluster(&edges, n, mcs);

            // labels in range
            assert!(c.labels.iter().all(|&l| l >= -1 && (l as i64) < c.n_clusters as i64));
            // every flat cluster has >= mcs points? Not guaranteed by EOM
            // (leaf clusters have >= mcs by construction of the condensed
            // tree, and selected clusters are condensed clusters) — check:
            let sizes = c.cluster_sizes();
            for &s in &sizes {
                assert!(s >= 1);
            }
            // selected clusters are disjoint: total clustered <= n
            assert!(c.n_clustered() <= n);
            // hierarchical counts are supersets of flat
            assert!(c.n_hierarchical_clustered() <= n);
            assert!(c.n_hierarchical_clusters() + 1 >= c.n_clusters);
        });
    }
}
