//! Exact HDBSCAN* baseline (the paper's comparison target \[27\]).
//!
//! Computes true core distances and the exact minimum spanning tree of the
//! complete mutual-reachability graph with Prim's algorithm in O(n²) time
//! and O(n) memory (the distance matrix is never materialized unless
//! requested — `matrix_mode` reproduces the paper's OOM behaviour on large
//! datasets by failing when the full matrix would not fit in the budget).

use crate::distances::Metric;
use crate::hdbscan::{cluster_from_msf, Clustering};
use crate::mst::Edge;

/// Configuration for the exact baseline.
#[derive(Clone, Copy, Debug)]
pub struct ExactParams {
    /// MinPts: neighbor count defining the core distance.
    pub min_pts: usize,
    /// Minimum cluster size (paper suggestion: = MinPts).
    pub mcs: usize,
    /// If set, precompute the full distance matrix (like feeding HDBSCAN*
    /// a pairwise matrix) and fail with `ExactError::OutOfMemory` when it
    /// exceeds this budget in bytes. `None` = streaming mode (O(n) memory,
    /// distances computed twice).
    pub matrix_budget: Option<usize>,
}

impl Default for ExactParams {
    fn default() -> Self {
        ExactParams { min_pts: 10, mcs: 10, matrix_budget: None }
    }
}

#[derive(Debug)]
pub enum ExactError {
    /// Simulates the paper's out-of-memory failures (Tables 7-8) when the
    /// full pairwise matrix exceeds the budget.
    OutOfMemory { required: usize, budget: usize },
}

impl std::fmt::Display for ExactError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExactError::OutOfMemory { required, budget } => write!(
                f,
                "distance matrix needs {required} bytes > budget {budget} (OOM)"
            ),
        }
    }
}

impl std::error::Error for ExactError {}

/// Outcome of the exact baseline, with cost accounting.
#[derive(Debug)]
pub struct ExactResult {
    pub clustering: Clustering,
    /// Total distance-function evaluations (the paper's cost model).
    pub dist_calls: u64,
}

/// Run exact HDBSCAN*.
pub fn exact_hdbscan<T, M: Metric<T>>(
    items: &[T],
    metric: &M,
    params: ExactParams,
) -> Result<ExactResult, ExactError> {
    let n = items.len();
    if n == 0 {
        return Ok(ExactResult {
            clustering: cluster_from_msf(&[], 1, params.mcs),
            dist_calls: 0,
        });
    }
    let mut dist_calls = 0u64;

    let matrix: Option<Vec<f32>> = match params.matrix_budget {
        Some(budget) => {
            let required = n * n * std::mem::size_of::<f32>();
            if required > budget {
                return Err(ExactError::OutOfMemory { required, budget });
            }
            let mut m = vec![0.0f32; n * n];
            for i in 0..n {
                for j in (i + 1)..n {
                    let d = metric.dist(&items[i], &items[j]) as f32;
                    dist_calls += 1;
                    m[i * n + j] = d;
                    m[j * n + i] = d;
                }
            }
            Some(m)
        }
        None => None,
    };

    let mut row_buf = vec![0.0f64; n];
    let fill_row = |i: usize, out: &mut [f64], dist_calls: &mut u64| {
        if let Some(m) = &matrix {
            for j in 0..n {
                out[j] = m[i * n + j] as f64;
            }
        } else {
            for j in 0..n {
                if j != i {
                    out[j] = metric.dist(&items[i], &items[j]);
                    *dist_calls += 1;
                } else {
                    out[j] = 0.0;
                }
            }
        }
    };

    // --- core distances: distance to the MinPts-th closest neighbor
    let k = params.min_pts.min(n.saturating_sub(1)).max(1);
    let mut core = vec![0.0f64; n];
    for i in 0..n {
        fill_row(i, &mut row_buf, &mut dist_calls);
        let mut ds: Vec<f64> = (0..n).filter(|&j| j != i).map(|j| row_buf[j]).collect();
        if ds.is_empty() {
            core[i] = 0.0;
            continue;
        }
        let kth = k - 1;
        ds.select_nth_unstable_by(kth, |a, b| a.total_cmp(b));
        core[i] = ds[kth];
    }

    // --- Prim's MST over the implicit mutual-reachability graph
    let mut in_tree = vec![false; n];
    let mut best = vec![f64::INFINITY; n];
    let mut best_from = vec![0u32; n];
    let mut edges: Vec<Edge> = Vec::with_capacity(n - 1);
    let mut current = 0usize;
    in_tree[0] = true;
    for _ in 1..n {
        fill_row(current, &mut row_buf, &mut dist_calls);
        let cc = core[current];
        let mut next = usize::MAX;
        let mut next_d = f64::INFINITY;
        for v in 0..n {
            if in_tree[v] {
                continue;
            }
            let mreach = row_buf[v].max(cc).max(core[v]);
            if mreach < best[v] {
                best[v] = mreach;
                best_from[v] = current as u32;
            }
            if best[v] < next_d {
                next_d = best[v];
                next = v;
            }
        }
        debug_assert!(next != usize::MAX);
        edges.push(Edge::new(best_from[next], next as u32, best[next]));
        in_tree[next] = true;
        current = next;
    }

    Ok(ExactResult {
        clustering: cluster_from_msf(&edges, n, params.mcs),
        dist_calls,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::vector::euclidean;
    use crate::util::rng::Rng;

    fn blobs(rng: &mut Rng, per: usize, centers: &[(f64, f64)], spread: f64) -> Vec<Vec<f32>> {
        let mut pts = Vec::new();
        for &(cx, cy) in centers {
            for _ in 0..per {
                pts.push(vec![
                    (cx + rng.normal() * spread) as f32,
                    (cy + rng.normal() * spread) as f32,
                ]);
            }
        }
        pts
    }

    fn metric() -> impl Metric<Vec<f32>> {
        |a: &Vec<f32>, b: &Vec<f32>| euclidean(a, b)
    }

    #[test]
    fn separates_clear_blobs() {
        let mut rng = Rng::new(1);
        let items = blobs(&mut rng, 30, &[(0.0, 0.0), (100.0, 100.0)], 1.0);
        let r = exact_hdbscan(&items, &metric(), ExactParams {
            min_pts: 5,
            mcs: 5,
            matrix_budget: None,
        })
        .unwrap();
        let c = &r.clustering;
        assert_eq!(c.n_clusters, 2, "labels {:?}", c.labels);
        // points within a blob share labels
        assert!(c.labels[..30].iter().all(|&l| l == c.labels[0] && l >= 0));
        assert!(c.labels[30..].iter().all(|&l| l == c.labels[30] && l >= 0));
        assert_ne!(c.labels[0], c.labels[30]);
    }

    #[test]
    fn quadratic_distance_calls() {
        let mut rng = Rng::new(2);
        let items = blobs(&mut rng, 20, &[(0.0, 0.0)], 1.0);
        let n = items.len() as u64;
        let r = exact_hdbscan(&items, &metric(), ExactParams::default()).unwrap();
        // streaming mode computes each row twice-ish: between n^2/2 and 2n^2
        assert!(r.dist_calls >= n * (n - 1) / 2);
        assert!(r.dist_calls <= 2 * n * n);
    }

    #[test]
    fn matrix_mode_matches_streaming() {
        let mut rng = Rng::new(3);
        let items = blobs(&mut rng, 25, &[(0.0, 0.0), (50.0, 0.0)], 2.0);
        let p = ExactParams { min_pts: 5, mcs: 5, matrix_budget: None };
        let a = exact_hdbscan(&items, &metric(), p).unwrap();
        let b = exact_hdbscan(
            &items,
            &metric(),
            ExactParams { matrix_budget: Some(usize::MAX), ..p },
        )
        .unwrap();
        assert_eq!(a.clustering.labels, b.clustering.labels);
        // matrix mode computes each pair once
        assert!(b.dist_calls < a.dist_calls);
    }

    #[test]
    fn oom_simulation() {
        let mut rng = Rng::new(4);
        let items = blobs(&mut rng, 100, &[(0.0, 0.0)], 1.0);
        let err = exact_hdbscan(
            &items,
            &metric(),
            ExactParams { min_pts: 5, mcs: 5, matrix_budget: Some(1024) },
        )
        .unwrap_err();
        assert!(matches!(err, ExactError::OutOfMemory { .. }));
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let items: Vec<Vec<f32>> = vec![];
        let r = exact_hdbscan(&items, &metric(), ExactParams::default()).unwrap();
        assert_eq!(r.dist_calls, 0);

        let items = vec![vec![0.0f32], vec![1.0f32]];
        let r = exact_hdbscan(&items, &metric(), ExactParams::default()).unwrap();
        assert_eq!(r.clustering.labels.len(), 2);
    }
}
