//! Single-linkage dendrogram and condensed-tree construction
//! (McInnes & Healy \[26\]'s bottom-up approach, paper Algorithm 1 CLUSTER).

use crate::mst::{Edge, UnionFind};

/// Scipy-style single-linkage dendrogram: merge i creates internal node
/// `n_points + i` joining two prior roots at a given distance.
#[derive(Clone, Debug)]
pub struct Dendrogram {
    pub n_points: usize,
    /// (left, right, distance, size) — size = points under the new node.
    pub merges: Vec<(u32, u32, f64, u32)>,
}

impl Dendrogram {
    /// Build from a minimum spanning forest. Edges need not be sorted.
    /// Forest components are joined by virtual merges at weight ∞, which
    /// produce the excluded root cluster (paper, Lemma 3.3).
    pub fn from_msf(edges: &[Edge], n_points: usize) -> Dendrogram {
        assert!(n_points > 0);
        let mut sorted: Vec<&Edge> = edges.iter().collect();
        sorted.sort_unstable_by(|x, y| x.w.total_cmp(&y.w));

        let mut uf = UnionFind::new(n_points);
        // current dendrogram node id for each UF root
        let mut node_of: Vec<u32> = (0..n_points as u32).collect();
        let mut size_of: Vec<u32> = vec![1; n_points];
        let mut merges = Vec::with_capacity(n_points - 1);
        let mut next_id = n_points as u32;

        let mut do_merge = |uf: &mut UnionFind,
                            node_of: &mut Vec<u32>,
                            size_of: &mut Vec<u32>,
                            merges: &mut Vec<(u32, u32, f64, u32)>,
                            a: u32,
                            b: u32,
                            w: f64|
         -> bool {
            let (ra, rb) = (uf.find(a), uf.find(b));
            if ra == rb {
                return false;
            }
            let (left, right) = (node_of[ra as usize], node_of[rb as usize]);
            let size = size_of[ra as usize] + size_of[rb as usize];
            uf.union(ra, rb);
            let root = uf.find(ra);
            node_of[root as usize] = next_id;
            size_of[root as usize] = size;
            merges.push((left, right, w, size));
            next_id += 1;
            true
        };

        for e in sorted {
            do_merge(&mut uf, &mut node_of, &mut size_of, &mut merges, e.a, e.b, e.w);
        }
        // join remaining components at infinity
        if uf.components() > 1 {
            let mut roots: Vec<u32> = Vec::new();
            let mut seen = std::collections::HashSet::new();
            for i in 0..n_points as u32 {
                let r = uf.find(i);
                if seen.insert(r) {
                    roots.push(i);
                }
            }
            let first = roots[0];
            for &other in &roots[1..] {
                do_merge(
                    &mut uf,
                    &mut node_of,
                    &mut size_of,
                    &mut merges,
                    first,
                    other,
                    f64::INFINITY,
                );
            }
        }
        debug_assert_eq!(merges.len(), n_points - 1);
        Dendrogram { n_points, merges }
    }

    /// Root node id (2*n_points - 2 when n_points > 1).
    pub fn root(&self) -> u32 {
        if self.n_points == 1 {
            0
        } else {
            (self.n_points + self.merges.len() - 1) as u32
        }
    }

    fn children(&self, node: u32) -> Option<(u32, u32, f64, u32)> {
        let i = (node as usize).checked_sub(self.n_points)?;
        Some(self.merges[i])
    }

    fn size(&self, node: u32) -> u32 {
        if (node as usize) < self.n_points {
            1
        } else {
            self.merges[node as usize - self.n_points].3
        }
    }
}

/// One condensed-tree row: `child` (a point id `< n_points`, or a cluster id
/// `>= n_points`) leaves `parent` at density `lambda` with `size` points.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CondensedRow {
    pub parent: u32,
    pub child: u32,
    pub lambda: f64,
    pub size: u32,
}

/// Condensed cluster hierarchy. Cluster ids are `n_points..`; the root
/// cluster is `n_points` and is excluded from flat selection.
#[derive(Clone, Debug)]
pub struct CondensedTree {
    pub n_points: usize,
    pub rows: Vec<CondensedRow>,
    /// Number of cluster ids allocated (root included).
    pub n_cluster_ids: usize,
}

/// Density lambda for a merge distance (λ = 1/d), capped for d → 0 and
/// mapped to 0 for the ∞-weight virtual merges.
#[inline]
pub fn lambda_of(dist: f64) -> f64 {
    const LAMBDA_CAP: f64 = 1e12;
    if dist.is_infinite() {
        0.0
    } else if dist <= 1.0 / LAMBDA_CAP {
        LAMBDA_CAP
    } else {
        1.0 / dist
    }
}

impl CondensedTree {
    /// Condense a dendrogram with minimum cluster size `mcs` (paper: set
    /// m_cs = MinPts). A split creates two child clusters iff both sides
    /// have >= mcs points; otherwise the small side's points "fall out" of
    /// the parent at that split's lambda.
    pub fn from_dendrogram(dendro: &Dendrogram, mcs: usize) -> CondensedTree {
        let n = dendro.n_points;
        let mcs = mcs.max(2) as u32;
        let root_cluster = n as u32;
        let mut rows = Vec::new();
        let mut next_cluster = root_cluster + 1;

        if n == 1 {
            return CondensedTree { n_points: 1, rows, n_cluster_ids: 1 };
        }

        // stack of (dendrogram node, condensed cluster it belongs to)
        let mut stack: Vec<(u32, u32)> = vec![(dendro.root(), root_cluster)];
        // reusable leaf-collection buffer
        let mut leaves = Vec::new();

        while let Some((node, cluster)) = stack.pop() {
            let Some((left, right, dist, _)) = dendro.children(node) else {
                // a bare point reached the stack directly (only possible for
                // virtual root chains); it falls out of `cluster` at λ of
                // its merge — handled by the parent below, so unreachable.
                unreachable!("leaf on traversal stack");
            };
            let lambda = lambda_of(dist);
            let (ls, rs) = (dendro.size(left), dendro.size(right));

            if ls >= mcs && rs >= mcs {
                // true split: two new clusters
                for &(child_node, child_size) in &[(left, ls), (right, rs)] {
                    let id = next_cluster;
                    next_cluster += 1;
                    rows.push(CondensedRow {
                        parent: cluster,
                        child: id,
                        lambda,
                        size: child_size,
                    });
                    stack.push((child_node, id));
                }
            } else if ls < mcs && rs < mcs {
                // cluster dissolves: every point falls out at this lambda
                for &side in &[left, right] {
                    collect_leaves(dendro, side, &mut leaves);
                    for &p in &leaves {
                        rows.push(CondensedRow {
                            parent: cluster,
                            child: p,
                            lambda,
                            size: 1,
                        });
                    }
                }
            } else {
                // one side survives as the same cluster, other side falls out
                let (survivor, casualty) = if ls >= mcs { (left, right) } else { (right, left) };
                collect_leaves(dendro, casualty, &mut leaves);
                for &p in &leaves {
                    rows.push(CondensedRow { parent: cluster, child: p, lambda, size: 1 });
                }
                if (survivor as usize) < n {
                    // single point surviving can't happen (size >= mcs >= 2)
                    unreachable!("point-sized survivor");
                }
                stack.push((survivor, cluster));
            }
        }

        CondensedTree {
            n_points: n,
            rows,
            n_cluster_ids: (next_cluster - root_cluster) as usize,
        }
    }

    pub fn root(&self) -> u32 {
        self.n_points as u32
    }

    /// Clusters excluding the root (Table 7 "hierarchical clusters").
    pub fn n_clusters_excluding_root(&self) -> usize {
        self.n_cluster_ids.saturating_sub(1)
    }

    /// Points that fall out of some non-root cluster (Table 7
    /// "hierarchical clustered elements").
    pub fn n_points_in_non_root_clusters(&self) -> usize {
        self.rows
            .iter()
            .filter(|r| r.size == 1 && (r.child as usize) < self.n_points)
            .filter(|r| r.parent != self.root())
            .count()
    }

    /// λ at which each cluster is born (appears as a child). Root: 0.
    pub fn birth_lambdas(&self) -> Vec<f64> {
        let mut birth = vec![0.0f64; self.n_cluster_ids];
        for r in &self.rows {
            if (r.child as usize) >= self.n_points {
                birth[(r.child as usize) - self.n_points] = r.lambda;
            }
        }
        birth
    }

    /// Excess-of-Mass stability per cluster id offset (id - n_points).
    pub fn stabilities(&self) -> Vec<f64> {
        let birth = self.birth_lambdas();
        let mut stab = vec![0.0f64; self.n_cluster_ids];
        for r in &self.rows {
            let pidx = (r.parent as usize) - self.n_points;
            stab[pidx] += (r.lambda - birth[pidx]) * r.size as f64;
        }
        stab
    }
}

/// Collect the point ids under a dendrogram node into `out` (cleared first).
fn collect_leaves(dendro: &Dendrogram, node: u32, out: &mut Vec<u32>) {
    out.clear();
    let mut stack = vec![node];
    while let Some(x) = stack.pop() {
        if (x as usize) < dendro.n_points {
            out.push(x);
        } else {
            let (l, r, _, _) = dendro.children(x).unwrap();
            stack.push(l);
            stack.push(r);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn chain_edges(n: usize, w: f64) -> Vec<Edge> {
        (0..n as u32 - 1).map(|i| Edge::new(i, i + 1, w)).collect()
    }

    #[test]
    fn dendrogram_shape() {
        let d = Dendrogram::from_msf(&chain_edges(5, 1.0), 5);
        assert_eq!(d.merges.len(), 4);
        assert_eq!(d.size(d.root()), 5);
    }

    #[test]
    fn dendrogram_on_forest_adds_virtual_root() {
        let edges = vec![Edge::new(0, 1, 1.0), Edge::new(2, 3, 1.0)];
        let d = Dendrogram::from_msf(&edges, 4);
        assert_eq!(d.merges.len(), 3);
        let (_, _, w, s) = d.merges[2];
        assert!(w.is_infinite());
        assert_eq!(s, 4);
    }

    #[test]
    fn lambda_mapping() {
        assert_eq!(lambda_of(f64::INFINITY), 0.0);
        assert_eq!(lambda_of(2.0), 0.5);
        assert!(lambda_of(0.0) >= 1e12);
    }

    #[test]
    fn condensed_sizes_and_conservation() {
        // two blobs of 5 at distance 1.0 internally, bridged at 10.0
        let mut edges = chain_edges(5, 1.0);
        for i in 0..4u32 {
            edges.push(Edge::new(5 + i, 6 + i, 1.0));
        }
        edges.push(Edge::new(0, 5, 10.0));
        let d = Dendrogram::from_msf(&edges, 10);
        let t = CondensedTree::from_dendrogram(&d, 3);
        // two clusters split from the root
        assert_eq!(t.n_clusters_excluding_root(), 2);
        // every point falls out exactly once
        let pts: Vec<u32> = t
            .rows
            .iter()
            .filter(|r| (r.child as usize) < 10)
            .map(|r| r.child)
            .collect();
        let mut sorted = pts.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn prop_condensed_invariants() {
        check("condense-invariants", 30, |rng, _| {
            // random MSF over n points: random tree with random weights
            let n = 5 + rng.below(80);
            let mut edges = Vec::new();
            for i in 1..n as u32 {
                let parent = rng.below(i as usize) as u32;
                edges.push(Edge::new(parent, i, rng.f64() * 10.0 + 0.01));
            }
            // randomly drop a few edges to create forests
            if rng.bool(0.3) && edges.len() > 2 {
                let k = rng.below(edges.len() / 2);
                for _ in 0..k {
                    let idx = rng.below(edges.len());
                    edges.swap_remove(idx);
                }
            }
            let mcs = 2 + rng.below(5);
            let d = Dendrogram::from_msf(&edges, n);
            let t = CondensedTree::from_dendrogram(&d, mcs);

            // (1) every point falls out exactly once
            let mut fallout = vec![0usize; n];
            for r in &t.rows {
                if (r.child as usize) < n {
                    assert_eq!(r.size, 1);
                    fallout[r.child as usize] += 1;
                }
            }
            assert!(fallout.iter().all(|&c| c == 1), "point fallout {fallout:?}");

            // (2) cluster rows have size >= mcs
            for r in &t.rows {
                if (r.child as usize) >= n {
                    assert!(r.size >= mcs as u32, "cluster child smaller than mcs");
                }
            }

            // (3) parent cluster size >= sum of points falling out of it
            // and >= each child cluster size
            let mut cluster_size = std::collections::HashMap::new();
            cluster_size.insert(t.root(), n as u32);
            for r in &t.rows {
                if (r.child as usize) >= n {
                    cluster_size.insert(r.child, r.size);
                }
            }
            for r in &t.rows {
                let ps = cluster_size[&r.parent];
                assert!(r.size <= ps, "child bigger than parent");
            }

            // (4) lambdas nonnegative, stabilities nonnegative
            assert!(t.rows.iter().all(|r| r.lambda >= 0.0));
            let stab = t.stabilities();
            assert!(
                stab.iter().all(|&s| s >= -1e-9),
                "negative stability {stab:?}"
            );

            // (5) λ(child cluster rows under parent) >= λ_birth(parent):
            // within a cluster, fall-out lambdas never precede its birth
            let birth = t.birth_lambdas();
            for r in &t.rows {
                let b = birth[(r.parent as usize) - n];
                assert!(
                    r.lambda >= b - 1e-9,
                    "row lambda {} before parent birth {b}",
                    r.lambda
                );
            }
        });
    }

    #[test]
    fn identical_points_zero_distance_edges() {
        // all points identical: every edge weight 0 → capped lambda
        let edges = chain_edges(6, 0.0);
        let d = Dendrogram::from_msf(&edges, 6);
        let t = CondensedTree::from_dendrogram(&d, 2);
        assert!(t.rows.iter().all(|r| r.lambda.is_finite()));
    }
}
