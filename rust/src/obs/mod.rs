//! Unified telemetry for the sharded engine — counters, gauges, latency
//! histograms, an epoch event journal, and zero-dependency exporters.
//!
//! # Why this layer exists
//!
//! The paper's cost model (Figs. 1–2) says FISHDBC runtime is dominated
//! by distance computations, and the engine already counts those — but
//! counts alone cannot answer the *serving-side* questions the ROADMAP
//! north-star poses: what is p99 [`Engine::label`] latency while a
//! background merge runs? How long does each merge phase take per epoch
//! (the per-stage breakdowns that made accelerated HDBSCAN* tunable in
//! McInnes & Healy, arXiv 1705.07321)? Is bridge coverage lagging
//! ingest? This module gives the engine distributions, spans, and a
//! lifecycle journal while keeping the repo's zero-external-crate
//! policy: everything here is `std` atomics, `std::net`, and hand-rolled
//! text formats.
//!
//! # Pieces
//!
//! * [`Registry`] — one per [`Engine`], never global, so concurrent
//!   tests stay isolated. Fixed metric schema (enums [`CounterId`],
//!   [`GaugeId`], [`HistId`] index pre-sized arrays; no maps, no string
//!   lookups on hot paths). Counters are striped across padded cache
//!   lines; recording a histogram sample is O(1) relaxed atomics.
//! * [`journal::Journal`] — bounded ring buffer of structured lifecycle
//!   events (merge start/end with changed-shard count and cache-hit
//!   kind, compactions, deletion windows, snapshot refreshes,
//!   save/load). Retrieved via `Engine::journal()`, dumped by the CLI
//!   with `--journal`.
//! * [`server::MetricsServer`] — a minimal hand-rolled HTTP/1.1
//!   responder on [`std::net::TcpListener`] serving `GET /metrics`
//!   (Prometheus text exposition) and `GET /stats.json`. This is the
//!   first networking brick for the ROADMAP serving layer.
//! * [`export`] — the Prometheus text and JSON renderers.
//!
//! # Metric reference (names as exported to Prometheus)
//!
//! | metric | kind | unit | meaning / paper mapping |
//! |---|---|---|---|
//! | `fishdbc_label_queries_total` | counter | calls | online `label()` queries (serving loop) |
//! | `fishdbc_ingest_items_total` | counter | items | items accepted by `add_batch` |
//! | `fishdbc_merges_total` | counter | epochs | published merge epochs |
//! | `fishdbc_merges_cache_{reused,delta,rebuild,scratch}_total` | counter | epochs | cache-hit kind per merge (Fig. 2's incremental-cost claim: `delta`/`reused` should dominate steady state) |
//! | `fishdbc_label_latency_seconds` | histogram | s | per-call `label()` latency — the serving p50/p99 |
//! | `fishdbc_ingest_batch_seconds` | histogram | s | `add_batch` call latency (incl. backpressure) |
//! | `fishdbc_span_*_seconds` | histogram | s | per-phase merge breakdown: bridge catch-up, window re-search, Kruskal fold, dendrogram, condense, extract, snapshot capture, compaction |
//! | `fishdbc_extract_seconds` | histogram | s | end-to-end parameterized extraction latency (`relabel_at`/`Tree`/`RelabelAt`; memo hits included — this is the "hierarchy as a service" serving cost) |
//! | `fishdbc_extractions_total` | counter | calls | parameterized extraction requests through the memo chain (merge path + on-demand) |
//! | `fishdbc_extract_memo_hits_total` | counter | calls | extraction requests answered bit-identically from the bounded memo |
//! | `fishdbc_serve_keepalive_requests_total` | counter | frames | requests after the first on a kept-alive `fishdbc serve` connection |
//! | `fishdbc_bridge_coverage_lag` | gauge | items | items not yet covered by insert-time bridging (paper §4's cross-shard recall risk when high) |
//! | `fishdbc_tombstone_ratio{shard=..}` | gauge | ratio | tombstoned / stored per shard (compaction pressure) |
//! | `fishdbc_epoch_age_seconds` | gauge | s | staleness of the served clustering |
//! | `fishdbc_serve_requests_total` | counter | frames | framed requests handled by `fishdbc serve` (per-op splits: `serve_{ping,stats,label,ingest,remove}_ops_total`) |
//! | `fishdbc_serve_busy_total` | counter | frames | requests refused with `Busy` (bounded-queue backpressure made visible) |
//! | `fishdbc_serve_request_seconds` | histogram | s | per-request network-serving latency, decode to encode |
//! | `fishdbc_wal_appends_total` / `fishdbc_wal_bytes_total` | counter | records / bytes | write-ahead-log journaling volume (durability layer) |
//! | `fishdbc_wal_fsyncs_total` | counter | calls | WAL group-commit fsyncs (one per durable ack round) |
//! | `fishdbc_wal_errors_total` | counter | failures | WAL append/fsync/checkpoint failures (sticky detail in `EngineStats::wal_last_error`) |
//! | `fishdbc_wal_replayed_total` | counter | records | records replayed at recovery — the O(Δ since checkpoint) witness |
//! | `fishdbc_checkpoints_total` | counter | files | durable checkpoints published (atomic rename + WAL trim) |
//! | `fishdbc_wal_fsync_seconds` | histogram | s | per-call WAL fsync latency (the durable-ack tax) |
//! | `fishdbc_checkpoint_seconds` | histogram | s | end-to-end checkpoint wall time |
//!
//! All histogram samples are recorded in nanoseconds internally and
//! exported in seconds (Prometheus convention). Quantiles are
//! upper-bound estimates with error bounded by one log2 bucket — see
//! [`hist`].
//!
//! [`Engine`]: crate::engine::Engine
//! [`Engine::label`]: crate::engine::Engine::label

pub mod export;
pub mod hist;
pub mod journal;
pub mod server;

pub use hist::{HistSnapshot, LogHistogram};
pub use journal::{CacheKind, Journal, JournalEntry, JournalEvent};
pub use server::MetricsServer;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Instant;

// ------------------------------------------------------------- schema --

macro_rules! metric_enum {
    ($(#[$m:meta])* $name:ident { $($(#[$vm:meta])* $v:ident => $s:literal, $help:literal;)+ }) => {
        $(#[$m])*
        #[derive(Clone, Copy, Debug, PartialEq, Eq)]
        #[repr(usize)]
        pub enum $name {
            $($(#[$vm])* $v,)+
        }
        impl $name {
            /// Every variant, in declaration (= storage) order.
            pub const ALL: &'static [$name] = &[$($name::$v,)+];
            /// Number of variants (array sizing).
            pub const COUNT: usize = Self::ALL.len();
            /// Stable exported metric name (snake_case, no prefix).
            pub fn name(self) -> &'static str {
                match self { $($name::$v => $s,)+ }
            }
            /// One-line human description (Prometheus `# HELP`).
            pub fn help(self) -> &'static str {
                match self { $($name::$v => $help,)+ }
            }
            #[inline]
            pub(crate) fn idx(self) -> usize {
                self as usize
            }
        }
    };
}

metric_enum! {
    /// Monotone event counters. Exported with a `_total` suffix.
    CounterId {
        LabelQueries => "label_queries",
            "Online label() queries served";
        IngestBatches => "ingest_batches",
            "add_batch calls accepted";
        IngestItems => "ingest_items",
            "Items accepted for ingest";
        Merges => "merges",
            "Published merge epochs";
        MergeReused => "merges_cache_reused",
            "Merges that republished the cached global forest unchanged";
        MergeDelta => "merges_cache_delta",
            "Merges that folded only changed shards into the cached forest";
        MergeRebuild => "merges_cache_rebuild",
            "Merges that re-folded all summaries (non-monotone window)";
        MergeScratch => "merges_cache_scratch",
            "Merges with no usable cache (first epoch or post-load)";
        PipelineRuns => "pipeline_runs",
            "Extraction pipeline invocations";
        PipelineShortCircuits => "pipeline_short_circuits",
            "Pipeline runs answered from the clustering cache";
        DendrogramReuses => "pipeline_dendrogram_reuses",
            "Pipeline runs that reused the cached dendrogram";
        Extractions => "extractions",
            "Parameterized extraction requests through the memo chain";
        ExtractMemoHits => "extract_memo_hits",
            "Extraction requests answered from the bounded extraction memo";
        SnapshotRefreshes => "snapshot_refreshes",
            "Mid-epoch frozen-snapshot refresh rounds";
        Compactions => "compactions",
            "Shard compactions (tombstone purges)";
        DeletionWindows => "deletion_windows",
            "remove_batch calls that tombstoned at least one item";
        Saves => "saves",
            "Engine checkpoints written";
        Loads => "loads",
            "Engine checkpoints restored";
        ServeConns => "serve_connections",
            "Connections claimed by the fishdbc serve handler pool";
        ServeRequests => "serve_requests",
            "Framed requests handled by fishdbc serve (all ops)";
        ServePings => "serve_ping_ops",
            "Ping frames answered";
        ServeStatsOps => "serve_stats_ops",
            "Stats frames answered";
        ServeLabelOps => "serve_label_ops",
            "Items labeled via Label/LabelBatch frames";
        ServeIngestOps => "serve_ingest_ops",
            "Items accepted via Ingest frames";
        ServeRemoveOps => "serve_remove_ops",
            "Items tombstoned via Remove frames";
        ServeTreeOps => "serve_tree_ops",
            "Condensed-hierarchy Tree frames answered";
        ServeRelabelOps => "serve_relabel_ops",
            "Items labeled via LabelAt/RelabelAt parameterized frames";
        ServeKeepaliveRequests => "serve_keepalive_requests",
            "Framed requests after the first on a kept-alive connection";
        ServeBusy => "serve_busy",
            "Requests refused with a Busy frame (saturated queue or pool)";
        ServeErrors => "serve_errors",
            "Requests answered with an Err frame (bad op, codec mismatch)";
        WalAppends => "wal_appends",
            "Batch records appended to the write-ahead log";
        WalBytes => "wal_bytes",
            "Bytes appended to the write-ahead log (frames included)";
        WalFsyncs => "wal_fsyncs",
            "WAL group-commit fsync calls";
        WalErrors => "wal_errors",
            "WAL append/fsync/checkpoint failures (see EngineStats::wal_last_error)";
        WalReplayed => "wal_replayed",
            "WAL records replayed during crash recovery (O(delta) witness)";
        Checkpoints => "checkpoints",
            "Durable checkpoints published (WAL-trimming epoch snapshots)";
    }
}

metric_enum! {
    /// Point-in-time gauges, refreshed on scrape / stats calls.
    GaugeId {
        BridgeCoverageLag => "bridge_coverage_lag",
            "Stored items not yet covered by insert-time cross-shard bridging";
        EpochAgeSecs => "epoch_age_seconds",
            "Seconds since the served epoch was published";
        LiveItems => "live_items",
            "Items stored and not tombstoned";
        Epoch => "epoch",
            "Latest published merge epoch";
    }
}

metric_enum! {
    /// Latency histograms (nanosecond samples, exported in seconds).
    HistId {
        Label => "label_latency_seconds",
            "Per-call online label() latency";
        Serve => "serve_request_seconds",
            "Per-request fishdbc serve handling latency (decode to encode)";
        IngestBatch => "ingest_batch_seconds",
            "add_batch call latency including routing and backpressure";
        ShardInsert => "shard_insert_seconds",
            "Per-batch shard-local HNSW insert time (worker side)";
        Merge => "merge_seconds",
            "End-to-end cluster()/merge latency per epoch";
        BridgeCatchUp => "span_bridge_catch_up_seconds",
            "Merge span: bridge catch-up over uncovered items";
        WindowResearch => "span_window_research_seconds",
            "Merge span: per-shard same-epoch window re-search";
        Kruskal => "span_kruskal_seconds",
            "Merge span: global Kruskal fold over summaries + bridges";
        Dendrogram => "span_dendrogram_seconds",
            "Pipeline span: single-linkage dendrogram build";
        Condense => "span_condense_seconds",
            "Pipeline span: condensed-tree construction";
        Extract => "span_extract_seconds",
            "Pipeline span: stable cluster extraction + labeling";
        ExtractCall => "extract_seconds",
            "End-to-end parameterized extraction latency (memo hits included)";
        SnapshotCapture => "span_snapshot_capture_seconds",
            "Span: chunked copy-on-write shard snapshot capture round";
        Compaction => "span_compaction_seconds",
            "Span: one shard compaction (survivor replay)";
        WalFsync => "wal_fsync_seconds",
            "Per-call WAL group-commit fsync latency";
        Checkpoint => "checkpoint_seconds",
            "End-to-end durable checkpoint wall time (cut to publish + trim)";
    }
}

// ----------------------------------------------------- striped counter --

/// Stripes per counter — enough to keep S ingest workers plus the merge
/// and serving threads off each other's cache lines without bloating the
/// registry (~25 counters x 8 stripes x 64 B = ~12.5 KiB).
const STRIPES: usize = 8;

static NEXT_STRIPE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Each thread picks a home stripe once; round-robin assignment
    /// spreads unrelated threads across lines.
    static HOME_STRIPE: usize =
        NEXT_STRIPE.fetch_add(1, Ordering::Relaxed) % STRIPES;
}

/// One cache line per stripe so concurrent recorders do not false-share.
#[repr(align(64))]
#[derive(Default)]
struct Stripe(AtomicU64);

/// A monotone counter sharded across padded atomic cells: `add` touches
/// only the calling thread's home stripe, `get` sums all stripes.
#[derive(Default)]
pub struct Counter {
    stripes: [Stripe; STRIPES],
}

impl Counter {
    /// Add `n`. O(1) relaxed RMW on the caller's home stripe.
    #[inline]
    pub fn add(&self, n: u64) {
        let s = HOME_STRIPE.with(|s| *s);
        self.stripes[s].0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current total across all stripes.
    pub fn get(&self) -> u64 {
        self.stripes
            .iter()
            .map(|s| s.0.load(Ordering::Relaxed))
            .sum()
    }
}

/// An `f64` gauge stored as bits in an atomic (set-wins, no RMW races).
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

// --------------------------------------------------------------- registry --

/// Per-engine telemetry registry: every counter, gauge, histogram, and
/// the event journal, allocated once at engine construction.
///
/// Not global by design — each [`Engine`](crate::engine::Engine) owns
/// its own `Arc<Registry>`, so parallel tests and embedded multi-engine
/// processes never share metric state. All recording methods take
/// `&self` and are lock-free except the journal (a short mutex push on
/// rare lifecycle events, never on the query path).
pub struct Registry {
    counters: Vec<Counter>,
    gauges: Vec<Gauge>,
    hists: Vec<LogHistogram>,
    /// Tombstone ratio per shard (label dimension fixed at spawn).
    shard_tombstone: Vec<Gauge>,
    /// Lifecycle event ring (see [`journal`]).
    pub journal: Journal,
    /// Time origin for uptime/epoch-age arithmetic.
    start: Instant,
    /// Nanoseconds-since-`start` of the latest epoch publish (0 = none).
    last_publish_ns: AtomicU64,
}

impl Registry {
    /// Build a registry for an engine with `n_shards` shards.
    pub fn new(n_shards: usize) -> Self {
        Registry {
            counters: (0..CounterId::COUNT).map(|_| Counter::default()).collect(),
            gauges: (0..GaugeId::COUNT).map(|_| Gauge::default()).collect(),
            hists: (0..HistId::COUNT).map(|_| LogHistogram::new()).collect(),
            shard_tombstone: (0..n_shards).map(|_| Gauge::default()).collect(),
            journal: Journal::new(journal::DEFAULT_CAPACITY),
            start: Instant::now(),
            last_publish_ns: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn counter(&self, id: CounterId) -> &Counter {
        &self.counters[id.idx()]
    }

    #[inline]
    pub fn gauge(&self, id: GaugeId) -> &Gauge {
        &self.gauges[id.idx()]
    }

    #[inline]
    pub fn hist(&self, id: HistId) -> &LogHistogram {
        &self.hists[id.idx()]
    }

    /// Increment a counter by 1.
    #[inline]
    pub fn inc(&self, id: CounterId) {
        self.counter(id).add(1);
    }

    /// Record an elapsed-time sample against `id`.
    #[inline]
    pub fn record(&self, id: HistId, d: std::time::Duration) {
        self.hist(id).record(d);
    }

    /// Record a seconds sample against `id` (for spans already measured
    /// as `f64` by the legacy timing code).
    #[inline]
    pub fn record_secs(&self, id: HistId, secs: f64) {
        let ns = (secs.max(0.0) * 1e9).round();
        self.hist(id).record_ns(if ns >= u64::MAX as f64 {
            u64::MAX
        } else {
            ns as u64
        });
    }

    /// Per-shard tombstone-ratio gauge (`shard < n_shards` as passed to
    /// [`Registry::new`]).
    pub fn shard_tombstone_gauge(&self, shard: usize) -> &Gauge {
        &self.shard_tombstone[shard]
    }

    /// Number of per-shard gauge slots.
    pub fn n_shards(&self) -> usize {
        self.shard_tombstone.len()
    }

    /// Seconds since the registry (= engine) was created.
    pub fn uptime_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Mark "an epoch was just published" — drives the epoch-age gauge.
    pub fn mark_publish(&self) {
        let ns = u64::try_from(self.start.elapsed().as_nanos())
            .unwrap_or(u64::MAX)
            .max(1);
        self.last_publish_ns.store(ns, Ordering::Relaxed);
    }

    /// Seconds since the last epoch publish; `None` before the first.
    pub fn epoch_age_secs(&self) -> Option<f64> {
        let at = self.last_publish_ns.load(Ordering::Relaxed);
        if at == 0 {
            return None;
        }
        Some((self.start.elapsed().as_secs_f64() - at as f64 / 1e9).max(0.0))
    }

    /// Point-in-time copy of every counter, gauge, and histogram, for
    /// export and for windowed diffing
    /// ([`Engine::stats_delta`](crate::engine::Engine::stats_delta)).
    pub fn snapshot(&self) -> RegistrySnapshot {
        RegistrySnapshot {
            counters: self.counters.iter().map(Counter::get).collect(),
            gauges: self.gauges.iter().map(Gauge::get).collect(),
            shard_tombstone: self
                .shard_tombstone
                .iter()
                .map(Gauge::get)
                .collect(),
            hists: self.hists.iter().map(LogHistogram::snapshot).collect(),
            uptime_secs: self.uptime_secs(),
        }
    }
}

/// Plain-data snapshot of a [`Registry`]; subtract two with
/// [`RegistrySnapshot::since`] for per-window rates.
#[derive(Clone, Debug)]
pub struct RegistrySnapshot {
    counters: Vec<u64>,
    gauges: Vec<f64>,
    shard_tombstone: Vec<f64>,
    hists: Vec<HistSnapshot>,
    /// Seconds since registry creation when the snapshot was taken.
    pub uptime_secs: f64,
}

impl RegistrySnapshot {
    pub fn counter(&self, id: CounterId) -> u64 {
        self.counters[id.idx()]
    }

    pub fn gauge(&self, id: GaugeId) -> f64 {
        self.gauges[id.idx()]
    }

    pub fn shard_tombstone(&self, shard: usize) -> f64 {
        self.shard_tombstone.get(shard).copied().unwrap_or(0.0)
    }

    pub fn n_shards(&self) -> usize {
        self.shard_tombstone.len()
    }

    pub fn hist(&self, id: HistId) -> &HistSnapshot {
        &self.hists[id.idx()]
    }

    /// Windowed difference (`self` later, `earlier` earlier): counters
    /// and histogram buckets subtract; gauges keep the later value.
    pub fn since(&self, earlier: &RegistrySnapshot) -> RegistrySnapshot {
        RegistrySnapshot {
            counters: self
                .counters
                .iter()
                .zip(&earlier.counters)
                .map(|(a, b)| a.saturating_sub(*b))
                .collect(),
            gauges: self.gauges.clone(),
            shard_tombstone: self.shard_tombstone.clone(),
            hists: self
                .hists
                .iter()
                .zip(&earlier.hists)
                .map(|(a, b)| a.since(b))
                .collect(),
            uptime_secs: (self.uptime_secs - earlier.uptime_secs).max(0.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_across_threads() {
        let reg = std::sync::Arc::new(Registry::new(2));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let reg = std::sync::Arc::clone(&reg);
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        reg.inc(CounterId::LabelQueries);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(reg.counter(CounterId::LabelQueries).get(), 40_000);
        assert_eq!(reg.counter(CounterId::Merges).get(), 0);
    }

    #[test]
    fn gauges_hold_latest_value() {
        let reg = Registry::new(3);
        reg.gauge(GaugeId::BridgeCoverageLag).set(12.5);
        reg.shard_tombstone_gauge(2).set(0.25);
        assert_eq!(reg.gauge(GaugeId::BridgeCoverageLag).get(), 12.5);
        assert_eq!(reg.shard_tombstone_gauge(2).get(), 0.25);
        assert_eq!(reg.shard_tombstone_gauge(0).get(), 0.0);
    }

    #[test]
    fn snapshot_since_gives_window_counts() {
        let reg = Registry::new(1);
        reg.counter(CounterId::IngestItems).add(100);
        reg.record_secs(HistId::Label, 0.001);
        let first = reg.snapshot();
        reg.counter(CounterId::IngestItems).add(50);
        reg.record_secs(HistId::Label, 0.002);
        reg.record_secs(HistId::Label, 0.004);
        let delta = reg.snapshot().since(&first);
        assert_eq!(delta.counter(CounterId::IngestItems), 50);
        assert_eq!(delta.hist(HistId::Label).count, 2);
    }

    #[test]
    fn epoch_age_tracks_publishes() {
        let reg = Registry::new(1);
        assert!(reg.epoch_age_secs().is_none());
        reg.mark_publish();
        let age = reg.epoch_age_secs().expect("published");
        assert!(age >= 0.0 && age < 60.0);
    }

    #[test]
    fn metric_names_are_unique_and_stable() {
        let mut names: Vec<&str> = CounterId::ALL
            .iter()
            .map(|c| c.name())
            .chain(GaugeId::ALL.iter().map(|g| g.name()))
            .chain(HistId::ALL.iter().map(|h| h.name()))
            .collect();
        let total = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), total, "duplicate exported metric name");
    }
}
