//! Log-bucketed latency histograms — lock-free quantiles for the hot paths.
//!
//! A [`LogHistogram`] covers the full `u64` nanosecond range with 64
//! fixed power-of-two buckets: a sample `v` lands in bucket
//! `64 - v.leading_zeros()` (bucket `k` holds `[2^(k-1), 2^k)`, bucket 0
//! holds the exact value 0). Recording is a handful of relaxed atomic
//! RMWs — one bucket increment, a count, a sum, and a `fetch_max` — so
//! the serving path (`Engine::label`) can record every call without a
//! lock and without allocating.
//!
//! Quantile estimates return the *upper bound* of the bucket holding the
//! requested rank, so for any exact sample value `v > 0` the estimate
//! `e` satisfies `v <= e < 2 * v`: the error is bounded by the bucket
//! width, which is the property test in this module pins. That factor-2
//! envelope is plenty to answer the serving questions the paper's cost
//! model raises (Figs. 1–2: runtime ∝ distance calls) — "does p99
//! `label()` latency see merge pauses" needs orders of magnitude, not
//! microsecond precision.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Number of log2 buckets — one per possible `u64` bit length, plus the
/// zero bucket folded into index 0.
pub const BUCKETS: usize = 64;

/// Lock-free log2-bucketed histogram over nanosecond samples.
///
/// All counters are relaxed atomics: `record` never blocks, never
/// allocates, and costs O(1) RMWs regardless of contention. Reads
/// (`snapshot`, `quantile_ns`) are not linearizable against concurrent
/// writers — they can observe a sample's bucket before its count or vice
/// versa — which is fine for monitoring and is why the concurrent stress
/// test only asserts totals after the writers join.
#[derive(Debug, Default)]
pub struct LogHistogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

/// Bucket index for a nanosecond sample: 0 for 0, else `64 - lz(v)`
/// clamped to the last bucket.
#[inline]
pub fn bucket_of(ns: u64) -> usize {
    if ns == 0 {
        0
    } else {
        ((64 - ns.leading_zeros()) as usize).min(BUCKETS - 1)
    }
}

/// Inclusive upper bound of bucket `idx` (the quantile estimate returned
/// for samples landing there).
#[inline]
pub fn bucket_upper_ns(idx: usize) -> u64 {
    if idx >= BUCKETS - 1 {
        u64::MAX
    } else {
        (1u64 << idx) - 1
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one nanosecond sample. O(1) relaxed atomics, no locks.
    #[inline]
    pub fn record_ns(&self, ns: u64) {
        self.buckets[bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// Record a [`Duration`] sample (saturating at `u64::MAX` ns).
    #[inline]
    pub fn record(&self, d: Duration) {
        self.record_ns(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all samples in nanoseconds (wraps after ~584 years of
    /// accumulated latency; acceptable).
    pub fn sum_ns(&self) -> u64 {
        self.sum_ns.load(Ordering::Relaxed)
    }

    /// Largest sample seen, exact (not bucketed).
    pub fn max_ns(&self) -> u64 {
        self.max_ns.load(Ordering::Relaxed)
    }

    /// Upper-bound estimate of the `q`-quantile (`0.0..=1.0`) in
    /// nanoseconds; 0 when empty.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        self.snapshot().quantile_ns(q)
    }

    /// Consistent-enough point-in-time copy for diffing and export.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut buckets = [0u64; BUCKETS];
        for (b, a) in buckets.iter_mut().zip(&self.buckets) {
            *b = a.load(Ordering::Relaxed);
        }
        HistSnapshot {
            buckets,
            count: self.count(),
            sum_ns: self.sum_ns(),
            max_ns: self.max_ns(),
        }
    }
}

/// Plain-data copy of a [`LogHistogram`], subtractable for windowed
/// stats ([`crate::engine::Engine::stats_delta`]).
#[derive(Clone, Copy, Debug)]
pub struct HistSnapshot {
    pub buckets: [u64; BUCKETS],
    pub count: u64,
    pub sum_ns: u64,
    pub max_ns: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        HistSnapshot { buckets: [0; BUCKETS], count: 0, sum_ns: 0, max_ns: 0 }
    }
}

impl HistSnapshot {
    /// Upper-bound estimate of the `q`-quantile in nanoseconds; 0 when
    /// the snapshot is empty.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        // rank of the requested quantile, 1-based ("nearest rank")
        let rank = ((q * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (idx, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                // the max is exact and always tighter than the last
                // occupied bucket's upper bound
                return bucket_upper_ns(idx).min(self.max_ns);
            }
        }
        self.max_ns
    }

    /// Quantile in seconds (export convenience).
    pub fn quantile_secs(&self, q: f64) -> f64 {
        self.quantile_ns(q) as f64 / 1e9
    }

    /// Per-window difference: `self` must be the later snapshot. The max
    /// is not subtractable, so the window max is the later cumulative max
    /// (an upper bound on the true window max).
    pub fn since(&self, earlier: &HistSnapshot) -> HistSnapshot {
        let mut buckets = [0u64; BUCKETS];
        for (i, b) in buckets.iter_mut().enumerate() {
            *b = self.buckets[i].saturating_sub(earlier.buckets[i]);
        }
        HistSnapshot {
            buckets,
            count: self.count.saturating_sub(earlier.count),
            sum_ns: self.sum_ns.saturating_sub(earlier.sum_ns),
            max_ns: self.max_ns,
        }
    }

    /// Mean sample in seconds; 0 when empty.
    pub fn mean_secs(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64 / 1e9
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    #[test]
    fn bucket_bounds_are_consistent() {
        // every representable value lands in a bucket whose upper bound
        // is >= the value and < 2x the value (the quantile error bound)
        for shift in 0..63 {
            for v in [1u64 << shift, (1u64 << shift) + 1, (1u64 << (shift + 1)) - 1] {
                let idx = bucket_of(v);
                let hi = bucket_upper_ns(idx);
                assert!(hi >= v, "upper bound {hi} below sample {v}");
                if hi != u64::MAX {
                    assert!(hi < v.saturating_mul(2), "bucket too wide at {v}");
                }
            }
        }
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_upper_ns(0), 0); // zero bucket is exact
    }

    /// Satellite: property test — quantile estimates vs exact sorted
    /// quantiles over random samples, error bounded by the bucket width
    /// (estimate in `[exact, 2*exact)` for positive samples).
    #[test]
    fn quantile_estimates_track_exact_quantiles() {
        let cases: usize = std::env::var("FISHDBC_PROPTEST_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1);
        for case in 0..cases.max(1) as u64 {
            let mut rng = Rng::new(0x1157 ^ case);
            for scale_bits in [10u32, 20, 30, 40] {
                let h = LogHistogram::new();
                let mut exact: Vec<u64> = (0..2000)
                    .map(|_| rng.next_u64() >> (64 - scale_bits))
                    .collect();
                for &v in &exact {
                    h.record_ns(v);
                }
                exact.sort_unstable();
                for &q in &[0.5, 0.9, 0.99, 1.0] {
                    let rank =
                        ((q * exact.len() as f64).ceil() as usize).max(1) - 1;
                    let truth = exact[rank];
                    let est = h.quantile_ns(q);
                    assert!(
                        est >= truth,
                        "q={q}: estimate {est} below exact {truth}"
                    );
                    if truth > 0 {
                        assert!(
                            est < truth.saturating_mul(2),
                            "q={q}: estimate {est} not within bucket width \
                             of exact {truth}"
                        );
                    } else {
                        assert!(est <= 1, "zero samples report ~0");
                    }
                }
                assert_eq!(h.count(), 2000);
                assert_eq!(h.max_ns(), *exact.last().unwrap());
                assert_eq!(h.sum_ns(), exact.iter().sum::<u64>());
            }
        }
    }

    /// Satellite: concurrent recorders lose no counts — 8 threads x 20k
    /// records each, totals must be exact after join.
    #[test]
    fn concurrent_recorders_lose_no_counts() {
        const THREADS: u64 = 8;
        const PER: u64 = 20_000;
        let h = Arc::new(LogHistogram::new());
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    let mut rng = Rng::new(0xC0C0 + t);
                    let mut local_sum = 0u64;
                    for _ in 0..PER {
                        let v = rng.next_u64() >> 34; // ~1s max in ns
                        h.record_ns(v);
                        local_sum += v;
                    }
                    local_sum
                })
            })
            .collect();
        let expect_sum: u64 =
            handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(h.count(), THREADS * PER, "lost sample counts");
        assert_eq!(h.sum_ns(), expect_sum, "lost sample sums");
        let snap = h.snapshot();
        assert_eq!(
            snap.buckets.iter().sum::<u64>(),
            THREADS * PER,
            "bucket totals disagree with the count"
        );
    }

    #[test]
    fn snapshot_since_subtracts_windows() {
        let h = LogHistogram::new();
        h.record_ns(100);
        h.record_ns(1000);
        let first = h.snapshot();
        h.record_ns(1_000_000);
        let delta = h.snapshot().since(&first);
        assert_eq!(delta.count, 1);
        assert_eq!(delta.sum_ns, 1_000_000);
        let q = delta.quantile_ns(0.5);
        assert!((1_000_000..2_000_000).contains(&q));
    }

    #[test]
    fn empty_histogram_is_quiet() {
        let h = LogHistogram::new();
        assert_eq!(h.quantile_ns(0.99), 0);
        assert_eq!(h.count(), 0);
        assert_eq!(h.snapshot().mean_secs(), 0.0);
    }
}
