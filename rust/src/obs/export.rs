//! Export renderers: Prometheus text exposition and hand-rolled JSON.
//!
//! Both formats render from a [`RegistrySnapshot`] — a plain-data copy —
//! so a scrape never holds engine locks while formatting. The Prometheus
//! renderer follows text exposition format 0.0.4 (`# HELP`/`# TYPE`
//! preambles, cumulative `_bucket{le="..."}` histogram series ending in
//! `+Inf`, `_sum`/`_count`). The JSON writer is the crate's only JSON
//! emitter: a tiny comma-tracking builder that maps non-finite floats to
//! `null`, so `python3 -m json.tool` (the CI schema check) always
//! accepts the output.

use super::hist::{bucket_upper_ns, HistSnapshot, BUCKETS};
use super::{CounterId, GaugeId, HistId, RegistrySnapshot};

/// Prefix every exported series shares.
pub const PROM_PREFIX: &str = "fishdbc_";

// ------------------------------------------------------------ prometheus --

/// Render the full registry as Prometheus text exposition. Extra
/// engine-level series (distance calls, item counts — values that live
/// outside the registry) ride along as `(name, help, value)` triples.
pub fn render_prometheus(
    snap: &RegistrySnapshot,
    extra_counters: &[(&str, &str, u64)],
    extra_gauges: &[(&str, &str, f64)],
) -> String {
    let mut out = String::with_capacity(8 * 1024);
    for &id in CounterId::ALL {
        let name = format!("{PROM_PREFIX}{}_total", id.name());
        preamble(&mut out, &name, id.help(), "counter");
        line_u64(&mut out, &name, snap.counter(id));
    }
    for (name, help, v) in extra_counters {
        let name = format!("{PROM_PREFIX}{name}_total");
        preamble(&mut out, &name, help, "counter");
        line_u64(&mut out, &name, *v);
    }
    for &id in GaugeId::ALL {
        let name = format!("{PROM_PREFIX}{}", id.name());
        preamble(&mut out, &name, id.help(), "gauge");
        line_f64(&mut out, &name, snap.gauge(id));
    }
    for (name, help, v) in extra_gauges {
        let name = format!("{PROM_PREFIX}{name}");
        preamble(&mut out, &name, help, "gauge");
        line_f64(&mut out, &name, *v);
    }
    if snap.n_shards() > 0 {
        let name = format!("{PROM_PREFIX}tombstone_ratio");
        preamble(
            &mut out,
            &name,
            "Tombstoned fraction of stored items, per shard",
            "gauge",
        );
        for si in 0..snap.n_shards() {
            out.push_str(&format!(
                "{name}{{shard=\"{si}\"}} {}\n",
                prom_f64(snap.shard_tombstone(si))
            ));
        }
    }
    for &id in HistId::ALL {
        let name = format!("{PROM_PREFIX}{}", id.name());
        preamble(&mut out, &name, id.help(), "histogram");
        render_prom_hist(&mut out, &name, snap.hist(id));
    }
    out
}

fn preamble(out: &mut String, name: &str, help: &str, kind: &str) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
}

fn line_u64(out: &mut String, name: &str, v: u64) {
    out.push_str(&format!("{name} {v}\n"));
}

fn line_f64(out: &mut String, name: &str, v: f64) {
    out.push_str(&format!("{name} {}\n", prom_f64(v)));
}

/// Prometheus float formatting: plain decimal, `NaN` for non-finite
/// (legal in the exposition format, unlike JSON).
fn prom_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "NaN".to_string()
    }
}

/// Cumulative `le` buckets in seconds; only buckets that move the
/// cumulative count are emitted (plus the mandatory `+Inf`).
fn render_prom_hist(out: &mut String, name: &str, h: &HistSnapshot) {
    let mut cum = 0u64;
    for idx in 0..BUCKETS {
        if h.buckets[idx] == 0 {
            continue;
        }
        cum += h.buckets[idx];
        let le = if idx >= BUCKETS - 1 {
            "+Inf".to_string()
        } else {
            format!("{:.9}", bucket_upper_ns(idx) as f64 / 1e9)
        };
        out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
    }
    out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
    out.push_str(&format!(
        "{name}_sum {}\n",
        prom_f64(h.sum_ns as f64 / 1e9)
    ));
    out.push_str(&format!("{name}_count {}\n", h.count));
}

// ------------------------------------------------------------------ json --

/// Minimal JSON writer: tracks "need a comma" per nesting level, escapes
/// strings, maps non-finite floats to `null`. The only JSON emitter in
/// the crate (zero-dependency policy).
pub struct JsonW {
    out: String,
    need_comma: Vec<bool>,
}

impl Default for JsonW {
    fn default() -> Self {
        Self::new()
    }
}

impl JsonW {
    pub fn new() -> Self {
        JsonW { out: String::with_capacity(4 * 1024), need_comma: vec![false] }
    }

    fn sep(&mut self) {
        if *self.need_comma.last().unwrap() {
            self.out.push(',');
        }
        *self.need_comma.last_mut().unwrap() = true;
    }

    /// Open an object; pass `Some(key)` inside an object, `None` as an
    /// array element or at the top level.
    pub fn obj(&mut self, key: Option<&str>) -> &mut Self {
        self.sep();
        if let Some(k) = key {
            self.push_key(k);
        }
        self.out.push('{');
        self.need_comma.push(false);
        self
    }

    pub fn end_obj(&mut self) -> &mut Self {
        self.need_comma.pop();
        self.out.push('}');
        self
    }

    pub fn arr(&mut self, key: Option<&str>) -> &mut Self {
        self.sep();
        if let Some(k) = key {
            self.push_key(k);
        }
        self.out.push('[');
        self.need_comma.push(false);
        self
    }

    pub fn end_arr(&mut self) -> &mut Self {
        self.need_comma.pop();
        self.out.push(']');
        self
    }

    pub fn u64(&mut self, key: &str, v: u64) -> &mut Self {
        self.sep();
        self.push_key(key);
        self.out.push_str(&v.to_string());
        self
    }

    pub fn usize(&mut self, key: &str, v: usize) -> &mut Self {
        self.u64(key, v as u64)
    }

    /// Finite floats print as plain decimals; NaN/inf become `null`.
    pub fn f64(&mut self, key: &str, v: f64) -> &mut Self {
        self.sep();
        self.push_key(key);
        if v.is_finite() {
            self.out.push_str(&format!("{v}"));
        } else {
            self.out.push_str("null");
        }
        self
    }

    pub fn bool(&mut self, key: &str, v: bool) -> &mut Self {
        self.sep();
        self.push_key(key);
        self.out.push_str(if v { "true" } else { "false" });
        self
    }

    pub fn str(&mut self, key: &str, v: &str) -> &mut Self {
        self.sep();
        self.push_key(key);
        self.push_escaped(v);
        self
    }

    fn push_key(&mut self, k: &str) {
        self.push_escaped(k);
        self.out.push(':');
    }

    fn push_escaped(&mut self, s: &str) {
        self.out.push('"');
        for c in s.chars() {
            match c {
                '"' => self.out.push_str("\\\""),
                '\\' => self.out.push_str("\\\\"),
                '\n' => self.out.push_str("\\n"),
                '\r' => self.out.push_str("\\r"),
                '\t' => self.out.push_str("\\t"),
                c if (c as u32) < 0x20 => {
                    self.out.push_str(&format!("\\u{:04x}", c as u32))
                }
                c => self.out.push(c),
            }
        }
        self.out.push('"');
    }

    pub fn finish(self) -> String {
        self.out
    }
}

/// Append one histogram as a JSON object under `key`: count, quantile
/// estimates in microseconds (honest units for sub-ms serving paths),
/// and the exact accumulated sum in seconds.
pub fn json_hist(w: &mut JsonW, key: &str, h: &HistSnapshot) {
    w.obj(Some(key))
        .u64("count", h.count)
        .f64("p50_us", h.quantile_ns(0.50) as f64 / 1e3)
        .f64("p90_us", h.quantile_ns(0.90) as f64 / 1e3)
        .f64("p99_us", h.quantile_ns(0.99) as f64 / 1e3)
        .f64("max_us", h.max_ns as f64 / 1e3)
        .f64("sum_secs", h.sum_ns as f64 / 1e9)
        .f64("mean_secs", h.mean_secs())
        .end_obj();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{HistId, Registry};

    #[test]
    fn prometheus_exposition_has_preambles_and_monotone_buckets() {
        let reg = Registry::new(2);
        reg.inc(CounterId::Merges);
        reg.gauge(GaugeId::Epoch).set(3.0);
        reg.shard_tombstone_gauge(1).set(0.5);
        for us in [5u64, 50, 500, 5000] {
            reg.hist(HistId::Label).record_ns(us * 1000);
        }
        let text = render_prometheus(
            &reg.snapshot(),
            &[("metric_calls", "distance metric invocations", 42)],
            &[("uptime_seconds", "seconds since spawn", 1.5)],
        );
        assert!(text.contains("# TYPE fishdbc_merges_total counter"));
        assert!(text.contains("fishdbc_merges_total 1\n"));
        assert!(text.contains("fishdbc_metric_calls_total 42\n"));
        assert!(text.contains("fishdbc_uptime_seconds 1.5\n"));
        assert!(text.contains("fishdbc_epoch 3\n"));
        assert!(text.contains("fishdbc_tombstone_ratio{shard=\"1\"} 0.5\n"));
        assert!(text.contains("fishdbc_label_latency_seconds_count 4\n"));
        assert!(text
            .contains("fishdbc_label_latency_seconds_bucket{le=\"+Inf\"} 4"));
        // cumulative bucket counts must be monotone nondecreasing
        let mut last = 0u64;
        for line in text.lines() {
            if let Some(rest) =
                line.strip_prefix("fishdbc_label_latency_seconds_bucket")
            {
                let v: u64 =
                    rest.rsplit(' ').next().unwrap().parse().unwrap();
                assert!(v >= last, "bucket counts regressed: {line}");
                last = v;
            }
        }
        assert_eq!(last, 4);
    }

    #[test]
    fn json_writer_emits_valid_structure() {
        let mut w = JsonW::new();
        w.obj(None)
            .str("schema", "test \"quoted\"\n")
            .u64("n", 7)
            .f64("ok", 1.25)
            .f64("bad", f64::NAN);
        w.arr(Some("xs"));
        for i in 0..3u64 {
            w.obj(None).u64("i", i).end_obj();
        }
        w.end_arr().end_obj();
        let s = w.finish();
        assert_eq!(
            s,
            "{\"schema\":\"test \\\"quoted\\\"\\n\",\"n\":7,\"ok\":1.25,\
             \"bad\":null,\"xs\":[{\"i\":0},{\"i\":1},{\"i\":2}]}"
        );
        assert!(!s.contains("NaN"));
    }

    #[test]
    fn json_hist_reports_quantiles() {
        let reg = Registry::new(1);
        for _ in 0..100 {
            reg.hist(HistId::Label).record_ns(1_000);
        }
        let mut w = JsonW::new();
        w.obj(None);
        json_hist(&mut w, "label", reg.snapshot().hist(HistId::Label));
        w.end_obj();
        let s = w.finish();
        assert!(s.contains("\"count\":100"));
        assert!(s.contains("\"p99_us\""));
    }
}
