//! Bounded ring-buffer epoch event journal.
//!
//! Every engine lifecycle event — merge start/end, compaction, deletion
//! window, snapshot refresh, save/load — appends one structured
//! [`JournalEntry`] to a fixed-capacity ring. When the ring is full the
//! *oldest* entry is dropped (newest always survive) and a drop counter
//! records the loss, so `Engine::journal()` is always an honest recent
//! history: seq numbers are gap-free among retained entries and strictly
//! increasing.
//!
//! The journal answers "what did the engine do and when" where the
//! [`Registry`](crate::obs::Registry) answers "how much / how fast":
//! a [`JournalEvent::MergeEnd`] carries the epoch number, how many
//! shards changed, and which cache path the merge took
//! ([`CacheKind`]) — the incremental-cost story of the paper's §4 made
//! inspectable per epoch. Pushes take a short mutex; nothing on the
//! query path ever touches it.

use std::collections::VecDeque;
use std::sync::Mutex;

/// Default ring capacity — enough for days of 30s-epoch operation
/// while bounding memory to a few hundred KB.
pub const DEFAULT_CAPACITY: usize = 1024;

/// Which path [`merge_forest`](crate::engine) took for one published
/// epoch — the journal's per-epoch cache-hit kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheKind {
    /// Nothing changed: the cached global forest was republished as-is.
    Reused,
    /// Monotone window: only changed shards were re-folded into the
    /// cached forest (the paper's O(Δn) recluster claim).
    Delta,
    /// Non-monotone window (deletions): every summary re-folded, but no
    /// bridge re-search and no per-shard recompute.
    Rebuild,
    /// No usable cache: first epoch, or first merge after a reload.
    Scratch,
}

impl CacheKind {
    /// Stable lower-case name used in JSON export and CLI dumps.
    pub fn name(self) -> &'static str {
        match self {
            CacheKind::Reused => "reused",
            CacheKind::Delta => "delta",
            CacheKind::Rebuild => "rebuild",
            CacheKind::Scratch => "scratch",
        }
    }
}

/// One structured lifecycle event.
#[derive(Clone, Debug, PartialEq)]
pub enum JournalEvent {
    /// A merge (`Engine::cluster`) began folding.
    MergeStart {
        /// Live id space the merge will cover.
        n_items: usize,
    },
    /// A merge published an epoch — exactly one of these per epoch.
    MergeEnd {
        /// The epoch number the snapshot was published under.
        epoch: u64,
        /// Shards whose stamp moved since the cached merge.
        n_changed_shards: usize,
        /// Which cache path the global fold took.
        cache: CacheKind,
        /// Id-space size of the published snapshot.
        n_items: usize,
        /// Deleted ids masked out of the published labels.
        n_deleted: usize,
        /// End-to-end merge wall time in seconds.
        secs: f64,
    },
    /// A parameterized extraction finished (`Engine::relabel_at`, the
    /// `Tree`/`LabelAt`/`RelabelAt` wire ops, or a merge's own
    /// extraction) — the hierarchy-as-a-service audit trail.
    ExtractionEnd {
        /// Epoch (= cached forest) the extraction was pinned to.
        epoch: u64,
        /// Minimum cluster size requested.
        mcs: usize,
        /// Eps threshold requested (0 outside the hybrid mode).
        eps: f64,
        /// Extraction mode name (`stability`/`leaf`/`hybrid_eps`).
        mode: &'static str,
        /// Whether the bounded extraction memo answered the request.
        cache_hit: bool,
    },
    /// A shard compacted its tombstones away.
    Compaction {
        shard: usize,
        /// Items surviving the compaction.
        survivors: usize,
    },
    /// A `remove_batch` call tombstoned items.
    DeletionWindow { removed: usize },
    /// A mid-epoch frozen-snapshot refresh round ran.
    SnapshotRefresh {
        /// Shards whose snapshot was actually re-captured.
        shards: usize,
    },
    /// The engine was checkpointed.
    Save { items: usize },
    /// The engine was restored from a checkpoint.
    Load { items: usize },
    /// The durability layer published a checkpoint (consistent cut →
    /// fsync → atomic rename → WAL trim).
    CheckpointEnd {
        /// Items covered by the published cut.
        items: usize,
        /// Ingest watermark the checkpoint covers (replay resumes after
        /// the matching WAL sequence).
        watermark: u64,
        /// End-to-end checkpoint wall time in seconds.
        secs: f64,
        /// WAL segments reclaimed by the post-publish trim.
        trimmed_segments: usize,
    },
    /// The engine was rebuilt at open: checkpoint load + WAL-suffix
    /// replay (`Durable::open`). `replayed_batches` is the O(Δ) recovery
    /// cost the `wal_replayed` counter also witnesses.
    Recovery {
        /// Items restored from the checkpoint container.
        checkpoint_items: usize,
        /// WAL records (ingest + remove) replayed past the cut.
        replayed_batches: usize,
        /// Items inside the replayed ingest records.
        replayed_items: usize,
    },
}

impl JournalEvent {
    /// Stable lower-snake event name (JSON `event` field, CLI dumps).
    pub fn name(&self) -> &'static str {
        match self {
            JournalEvent::MergeStart { .. } => "merge_start",
            JournalEvent::MergeEnd { .. } => "merge_end",
            JournalEvent::ExtractionEnd { .. } => "extraction_end",
            JournalEvent::Compaction { .. } => "compaction",
            JournalEvent::DeletionWindow { .. } => "deletion_window",
            JournalEvent::SnapshotRefresh { .. } => "snapshot_refresh",
            JournalEvent::Save { .. } => "save",
            JournalEvent::Load { .. } => "load",
            JournalEvent::CheckpointEnd { .. } => "checkpoint_end",
            JournalEvent::Recovery { .. } => "recovery",
        }
    }
}

/// One journal record: a monotone sequence number, seconds since the
/// engine started, and the event payload.
#[derive(Clone, Debug, PartialEq)]
pub struct JournalEntry {
    /// Strictly increasing, gap-free among all pushed events (dropped
    /// entries leave the low seqs missing, never the high ones).
    pub seq: u64,
    /// Engine-relative timestamp in seconds (registry uptime clock).
    pub at_secs: f64,
    pub event: JournalEvent,
}

struct JournalInner {
    ring: VecDeque<JournalEntry>,
    next_seq: u64,
    dropped: u64,
}

/// Fixed-capacity, oldest-drop event ring. See the module docs.
pub struct Journal {
    cap: usize,
    inner: Mutex<JournalInner>,
}

impl Journal {
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        Journal {
            cap,
            inner: Mutex::new(JournalInner {
                ring: VecDeque::with_capacity(cap.min(DEFAULT_CAPACITY)),
                next_seq: 0,
                dropped: 0,
            }),
        }
    }

    /// Append one event stamped `at_secs`; drops the oldest entry when
    /// full. Poison-tolerant: a panicked pusher never wedges readers.
    pub fn push(&self, at_secs: f64, event: JournalEvent) {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let seq = g.next_seq;
        g.next_seq += 1;
        if g.ring.len() == self.cap {
            g.ring.pop_front();
            g.dropped += 1;
        }
        g.ring.push_back(JournalEntry { seq, at_secs, event });
    }

    /// All retained entries, oldest first.
    pub fn entries(&self) -> Vec<JournalEntry> {
        let g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.ring.iter().cloned().collect()
    }

    /// Entries evicted by ring wrap so far.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).dropped
    }

    /// Total events ever pushed (retained + dropped).
    pub fn total(&self) -> u64 {
        self.inner
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .next_seq
    }

    /// Ring capacity.
    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(n: usize) -> JournalEvent {
        JournalEvent::DeletionWindow { removed: n }
    }

    /// Satellite: the ring wraps without losing the *newest* entries —
    /// oldest are evicted, seqs stay strictly increasing and gap-free.
    #[test]
    fn ring_wrap_keeps_newest_entries() {
        let j = Journal::new(8);
        for i in 0..20 {
            j.push(i as f64, ev(i));
        }
        let entries = j.entries();
        assert_eq!(entries.len(), 8);
        assert_eq!(j.dropped(), 12);
        assert_eq!(j.total(), 20);
        // newest 8 events, in order, gap-free seqs
        for (k, e) in entries.iter().enumerate() {
            assert_eq!(e.seq, 12 + k as u64);
            assert_eq!(e.event, ev(12 + k));
        }
    }

    #[test]
    fn capacity_is_at_least_one() {
        let j = Journal::new(0);
        j.push(0.0, ev(1));
        j.push(0.1, ev(2));
        let entries = j.entries();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].event, ev(2), "newest survives at cap 1");
    }

    #[test]
    fn concurrent_pushers_keep_seqs_unique() {
        let j = std::sync::Arc::new(Journal::new(64));
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let j = std::sync::Arc::clone(&j);
                std::thread::spawn(move || {
                    for i in 0..100 {
                        j.push(0.0, ev(t * 1000 + i));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(j.total(), 400);
        let entries = j.entries();
        assert_eq!(entries.len(), 64);
        for w in entries.windows(2) {
            assert!(w[0].seq < w[1].seq, "seqs must strictly increase");
        }
        assert_eq!(entries.last().unwrap().seq, 399);
    }

    #[test]
    fn event_names_are_stable() {
        assert_eq!(
            JournalEvent::MergeEnd {
                epoch: 1,
                n_changed_shards: 0,
                cache: CacheKind::Reused,
                n_items: 0,
                n_deleted: 0,
                secs: 0.0,
            }
            .name(),
            "merge_end"
        );
        assert_eq!(CacheKind::Delta.name(), "delta");
    }
}
