//! Minimal hand-rolled HTTP/1.1 responder for `GET /metrics`.
//!
//! Zero dependencies: a [`std::net::TcpListener`] in non-blocking accept
//! mode, one short-lived thread per connection, and just enough HTTP to
//! satisfy a Prometheus scraper — request-line parsing, fragmented-read
//! tolerance (the request is buffered until the blank line), fixed
//! `Content-Length` responses, `Connection: close`. This is deliberately
//! the smallest networking brick that can serve an exposition; the
//! ROADMAP serving layer will grow from it.
//!
//! The server owns only a *render callback*, not the engine: the engine
//! side hands in a closure over a [`Weak`](std::sync::Weak) engine
//! reference, so a dropped engine degrades scrapes gracefully (the
//! registry keeps rendering its last totals; live-state paths 404)
//! instead of keeping the whole engine alive or panicking. Teardown is
//! poison-tolerant and bounded: dropping [`MetricsServer`] stops the
//! accept loop and joins every in-flight connection thread (each capped
//! by a read timeout).

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Maximum buffered request size; anything larger is answered 400.
const MAX_REQUEST: usize = 8 * 1024;
/// Per-connection socket read/write timeout.
const IO_TIMEOUT: Duration = Duration::from_secs(2);
/// Accept-loop poll interval while idle.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// Route callback: path → `Some((body, content_type))` or `None` (404).
pub type Render =
    dyn Fn(&str) -> Option<(String, &'static str)> + Send + Sync;

/// A running metrics endpoint. Dropping it shuts the listener down and
/// joins all connection threads.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9100`; port 0 picks a free port —
    /// read it back from [`MetricsServer::addr`]) and serve `render` on
    /// a background thread until dropped.
    pub fn serve(addr: &str, render: Arc<Render>) -> io::Result<MetricsServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        // thread-spawn failure (EAGAIN under pid exhaustion) is an io
        // error like any other bind failure: propagate, don't panic
        let accept = std::thread::Builder::new()
            .name("fishdbc-metrics".into())
            .spawn(move || accept_loop(listener, stop2, render))?;
        Ok(MetricsServer { addr, stop, accept: Some(accept) })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            // the accept thread joins its connection threads before
            // returning; a panicked handler never wedges the teardown
            let _ = h.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    render: Arc<Render>,
) {
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                conns.retain(|h| !h.is_finished());
                let render = Arc::clone(&render);
                // concurrent scrapes each get their own thread; a slow
                // client only stalls itself (bounded by IO_TIMEOUT)
                if let Ok(h) = std::thread::Builder::new()
                    .name("fishdbc-metrics-conn".into())
                    .spawn(move || {
                        let _ = handle_conn(stream, &render);
                    })
                {
                    conns.push(h);
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
    for h in conns {
        let _ = h.join();
    }
}

/// Read one request (tolerating arbitrary fragmentation), answer it,
/// close. Any socket error just drops the connection.
fn handle_conn(mut stream: TcpStream, render: &Arc<Render>) -> io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;

    // fragmented reads: keep appending until the header terminator
    // arrives, the client gives up, or the request is implausibly large
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 1024];
    let headers_end = loop {
        if let Some(end) = find_headers_end(&buf) {
            break Some(end);
        }
        if buf.len() > MAX_REQUEST {
            break None;
        }
        match stream.read(&mut chunk) {
            Ok(0) => break None,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(_) => break None,
        }
    };
    if headers_end.is_none() {
        return respond(
            &mut stream,
            400,
            "Bad Request",
            "text/plain",
            "bad request\n",
        );
    }

    let head = String::from_utf8_lossy(&buf);
    let mut parts = head.lines().next().unwrap_or("").split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("/");
    // HEAD (load-balancer health probes) gets the same status line and
    // headers — Content-Length included — with the body suppressed
    let head_only = method == "HEAD";
    if method != "GET" && !head_only {
        // RFC 7231 §6.5.5: a 405 must name the allowed methods
        return respond_with(
            &mut stream,
            405,
            "Method Not Allowed",
            "text/plain",
            "only GET and HEAD are supported\n",
            &[("Allow", "GET, HEAD")],
            false,
        );
    }
    // ignore any query string: /metrics?x=1 is still /metrics
    let path = path.split('?').next().unwrap_or(path);
    match render(path) {
        Some((body, ctype)) => {
            respond_with(&mut stream, 200, "OK", ctype, &body, &[], head_only)
        }
        None => respond_with(
            &mut stream,
            404,
            "Not Found",
            "text/plain",
            "not found\n",
            &[],
            head_only,
        ),
    }
}

/// Offset one past the blank line ending the headers, or `None` if the
/// buffer does not contain a complete header block yet. Accepts both the
/// canonical `\r\n\r\n` terminator and a bare-LF `\n\n` one (RFC 7230
/// §3.5 says a robust parser MAY tolerate LF alone); with mixed endings
/// (`...\r\n\n`) the earlier terminator wins.
fn find_headers_end(buf: &[u8]) -> Option<usize> {
    let crlf = buf.windows(4).position(|w| w == b"\r\n\r\n").map(|p| p + 4);
    let lf = buf.windows(2).position(|w| w == b"\n\n").map(|p| p + 2);
    match (crlf, lf) {
        (Some(a), Some(b)) => Some(a.min(b)),
        (a, b) => a.or(b),
    }
}

fn respond(
    stream: &mut TcpStream,
    code: u16,
    reason: &str,
    ctype: &str,
    body: &str,
) -> io::Result<()> {
    respond_with(stream, code, reason, ctype, body, &[], false)
}

/// Write a response; `extra` headers follow the fixed ones, and
/// `head_only` keeps the advertised `Content-Length` while suppressing
/// the body itself (HEAD semantics).
fn respond_with(
    stream: &mut TcpStream,
    code: u16,
    reason: &str,
    ctype: &str,
    body: &str,
    extra: &[(&str, &str)],
    head_only: bool,
) -> io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: {ctype}\r\n\
         Content-Length: {}\r\nConnection: close\r\n",
        body.len()
    );
    for (name, value) in extra {
        head.push_str(name);
        head.push_str(": ");
        head.push_str(value);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    if !head_only {
        stream.write_all(body.as_bytes())?;
    }
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn start() -> MetricsServer {
        MetricsServer::serve(
            "127.0.0.1:0",
            Arc::new(|path: &str| match path {
                "/metrics" => {
                    Some(("fishdbc_up 1\n".to_string(), "text/plain"))
                }
                _ => None,
            }),
        )
        .expect("bind")
    }

    fn get(addr: SocketAddr, request: &str) -> String {
        let mut s = TcpStream::connect(addr).expect("connect");
        s.write_all(request.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_metrics_and_404s_unknown_paths() {
        let srv = start();
        let ok = get(srv.addr(), "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(ok.starts_with("HTTP/1.1 200 OK"), "got: {ok}");
        assert!(ok.contains("fishdbc_up 1"));
        let missing = get(srv.addr(), "GET /nope HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(missing.starts_with("HTTP/1.1 404"), "got: {missing}");
        let query =
            get(srv.addr(), "GET /metrics?x=1 HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(query.starts_with("HTTP/1.1 200"), "got: {query}");
    }

    #[test]
    fn tolerates_fragmented_requests() {
        let srv = start();
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        for frag in ["GE", "T /met", "rics HTTP/1.1\r\nHo", "st: x\r\n\r\n"] {
            s.write_all(frag.as_bytes()).unwrap();
            s.flush().unwrap();
            std::thread::sleep(Duration::from_millis(10));
        }
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 200 OK"), "got: {out}");
    }

    #[test]
    fn rejects_non_get() {
        let srv = start();
        let resp =
            get(srv.addr(), "POST /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 405"), "got: {resp}");
        // RFC 7231 §6.5.5: the 405 must carry an Allow header
        assert!(resp.contains("Allow: GET, HEAD"), "got: {resp}");
    }

    #[test]
    fn bare_lf_requests_answer_without_stalling() {
        // `printf 'GET /metrics HTTP/1.0\n\n' | nc` — RFC 7230 §3.5 bare
        // LF tolerance; before the fix this stalled for the full
        // IO_TIMEOUT and then got a 400
        let srv = start();
        let t0 = std::time::Instant::now();
        let resp = get(srv.addr(), "GET /metrics HTTP/1.0\n\n");
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "got: {resp}");
        assert!(resp.contains("fishdbc_up 1"));
        assert!(
            t0.elapsed() < IO_TIMEOUT,
            "bare-LF request waited out the read timeout: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn head_serves_headers_only_with_body_length() {
        let srv = start();
        let resp = get(srv.addr(), "HEAD /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "got: {resp}");
        // Content-Length advertises the GET body ("fishdbc_up 1\n" = 13
        // bytes) but the body itself is suppressed
        assert!(resp.contains("Content-Length: 13"), "got: {resp}");
        assert!(!resp.contains("fishdbc_up"), "HEAD leaked a body: {resp}");
        assert!(resp.ends_with("\r\n\r\n"), "got: {resp:?}");
        // HEAD on an unknown path keeps 404 semantics
        let missing =
            get(srv.addr(), "HEAD /nope HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(missing.starts_with("HTTP/1.1 404"), "got: {missing}");
        assert!(!missing.contains("not found\n"), "got: {missing:?}");
    }

    #[test]
    fn concurrent_scrapes_all_answer() {
        let srv = start();
        let addr = srv.addr();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                std::thread::spawn(move || {
                    get(addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n")
                })
            })
            .collect();
        for h in handles {
            let resp = h.join().unwrap();
            assert!(resp.starts_with("HTTP/1.1 200 OK"));
        }
    }

    #[test]
    fn shutdown_releases_the_port() {
        let srv = start();
        let addr = srv.addr();
        drop(srv);
        // the port must be rebindable once drop returns
        let rebound = TcpListener::bind(addr);
        assert!(rebound.is_ok(), "port still held after shutdown");
    }
}
