//! Hierarchical Navigable Small World index (Malkov & Yashunin \[24\]),
//! adapted for FISHDBC (paper §3): the index is only ever *built*, never
//! queried, and **every distance evaluation is logged** so the caller can
//! piggyback candidate MST edges on insertion work.
//!
//! Parameters follow the paper: `k = M = MinPts` neighbors per node,
//! `ef` is the construction beam width (paper evaluates ef ∈ {20, 50}),
//! remaining parameters at Malkov & Yashunin defaults (`M_max0 = 2M`,
//! level multiplier `mL = 1/ln(M)`, select-neighbors heuristic with pruned
//! connection keeping).

use crate::distances::{sanitize_distance, Metric};
use crate::util::chunked::{ChunkDelta, ChunkedVec, ItemStore};
use crate::util::rng::Rng;

/// A logged distance evaluation: (node a, node b, d(a, b)).
pub type DistLog = Vec<(u32, u32, f64)>;

/// Ordered f64 wrapper so distances can live in heaps.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OrdF64(pub f64);

impl Eq for OrdF64 {}

impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Words before the per-level lengths in [`Node::data`]: `n_levels`,
/// `cap0`, `capl`.
const NODE_HDR: usize = 3;

/// Per-node adjacency in **one flat allocation** (SmallVec-style inline
/// capacity): beam search walks a node's neighbors as one contiguous
/// `&[u32]` instead of pointer-chasing a `Vec<Vec<u32>>` — one heap block
/// per node instead of `levels + 1`, and `Clone` (the copy-on-write path
/// when a snapshot pins a chunk) is a single `memcpy`.
///
/// Layout of `data`: `[n_levels, cap0, capl, len[0..n_levels],
/// slots(level 0: cap0 words)(levels 1..: capl words each)]`. Capacities
/// carry one slot of slack over the degree bounds (`cap0 = 2m + 1`,
/// `capl = m + 1`) so [`Hnsw::link`]'s push-then-shrink transient
/// overflow stays in place. The layout is in-memory only: export/import
/// speak nested lists, so persisted bytes are unchanged.
#[derive(Clone, Debug)]
struct Node {
    data: Box<[u32]>,
}

impl Node {
    /// Empty node spanning levels `0..=level` with explicit capacities.
    fn with_caps(level: usize, cap0: usize, capl: usize) -> Node {
        let n_levels = level + 1;
        let mut data = vec![0u32; NODE_HDR + n_levels + cap0 + level * capl];
        data[0] = n_levels as u32;
        data[1] = cap0 as u32;
        data[2] = capl as u32;
        Node { data: data.into_boxed_slice() }
    }

    /// Empty node with the standard slack capacities for parameter `m`.
    fn with_capacity(level: usize, m: usize) -> Node {
        Node::with_caps(level, 2 * m + 1, m + 1)
    }

    /// Rebuild from nested lists (import path). Capacities are the
    /// standard ones for `m` — self-produced exports always fit, so a
    /// round-tripped index continues exactly like the original — widened
    /// (plus slack) only for foreign files with oversized lists.
    fn from_lists(lists: &[Vec<u32>], m: usize) -> Node {
        let level = lists.len() - 1;
        let cap0 = (2 * m + 1).max(lists[0].len() + 1);
        let widest = lists[1..].iter().map(Vec::len).max().unwrap_or(0);
        let capl = (m + 1).max(widest + 1);
        let mut n = Node::with_caps(level, cap0, capl);
        for (l, list) in lists.iter().enumerate() {
            n.set_links(l, list);
        }
        n
    }

    /// Nested-list view (export path).
    fn to_lists(&self) -> Vec<Vec<u32>> {
        (0..self.n_levels()).map(|l| self.links(l).to_vec()).collect()
    }

    #[inline]
    fn n_levels(&self) -> usize {
        self.data[0] as usize
    }

    #[inline]
    fn level(&self) -> usize {
        self.n_levels() - 1
    }

    #[inline]
    fn len(&self, l: usize) -> usize {
        debug_assert!(l < self.n_levels());
        self.data[NODE_HDR + l] as usize
    }

    #[inline]
    fn cap(&self, l: usize) -> usize {
        if l == 0 { self.data[1] as usize } else { self.data[2] as usize }
    }

    #[inline]
    fn slot_base(&self, l: usize) -> usize {
        debug_assert!(l < self.n_levels());
        let base = NODE_HDR + self.n_levels();
        if l == 0 {
            base
        } else {
            base + self.data[1] as usize + (l - 1) * self.data[2] as usize
        }
    }

    /// Neighbor ids at level `l` — one contiguous slice, no indirection.
    #[inline]
    fn links(&self, l: usize) -> &[u32] {
        let b = self.slot_base(l);
        &self.data[b..b + self.len(l)]
    }

    #[inline]
    fn push_link(&mut self, l: usize, v: u32) {
        let len = self.len(l);
        assert!(len < self.cap(l), "link slots exhausted at level {l}");
        let b = self.slot_base(l);
        self.data[b + len] = v;
        self.data[NODE_HDR + l] = (len + 1) as u32;
    }

    fn set_links(&mut self, l: usize, links: &[u32]) {
        assert!(links.len() <= self.cap(l), "links exceed level {l} capacity");
        let b = self.slot_base(l);
        self.data[b..b + links.len()].copy_from_slice(links);
        self.data[NODE_HDR + l] = links.len() as u32;
    }
}

/// HNSW construction parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HnswParams {
    /// Neighbors per node on levels > 0 (the paper sets M = MinPts).
    pub m: usize,
    /// Construction beam width (paper's headline knob: 20 or 50).
    pub ef: usize,
    /// RNG seed for level assignment.
    pub seed: u64,
}

impl Default for HnswParams {
    fn default() -> Self {
        HnswParams { m: 10, ef: 20, seed: 0xF15D }
    }
}

/// Exported HNSW state (persistence interchange; see [`Hnsw::export`]).
/// Always dense (`Vec` of per-node link lists): the chunked in-memory
/// layout never reaches the on-disk format, so files written before and
/// after the copy-on-write refactor are byte-identical.
#[derive(Clone, Debug, PartialEq)]
pub struct HnswExport {
    pub params: HnswParams,
    /// `links[id][level]` = neighbor ids.
    pub links: Vec<Vec<Vec<u32>>>,
    pub entry: Option<u32>,
    pub rng_state: [u64; 4],
    pub dist_calls: u64,
}

/// The index. Generic over item type `T`; the item store lives in the
/// caller (FISHDBC keeps one [`ChunkedVec<T>`] shared by HNSW and output)
/// and is passed to [`Hnsw::add`] each time as any [`ItemStore`], keeping
/// borrows simple.
///
/// Node/link storage is chunked copy-on-write ([`ChunkedVec`]): cloning
/// the index is O(n / CHUNK) `Arc` copies, and only chunks whose nodes
/// were rewired after the clone are ever physically copied — the engine's
/// frozen [`ShardSnap`](crate::engine)s lean on exactly this to make
/// snapshot refreshes O(Δ) instead of O(n).
#[derive(Debug)]
pub struct Hnsw {
    params: HnswParams,
    nodes: ChunkedVec<Node>,
    entry: Option<u32>,
    rng: Rng,
    mult: f64,
    dist_calls: u64,
    /// Batched distance dispatches on the build path — each covered
    /// `ids.len()` pairwise evaluations already counted in `dist_calls`.
    /// Telemetry only (CI asserts the batch path is exercised): carried
    /// across clones like `dist_calls`, but **not** part of the persisted
    /// interchange — FISHENG bytes are unchanged; import restarts it at 0.
    batch_evals: u64,
    // --- transient perf state (not persisted) ---
    /// Epoch-stamped visited marks: `visited_mark[id] == epoch` ⇔ visited
    /// in the current search. Avoids a HashSet allocation per search_layer
    /// call (§Perf: ~15% of insert time at n=8k).
    visited_mark: Vec<u32>,
    epoch: u32,
    /// Reusable frontier buffer (avoids cloning neighbor lists).
    scratch: Vec<u32>,
    /// Reusable distance buffer, paired with `scratch` by the batched
    /// evaluation path.
    scratch_d: Vec<f64>,
}

impl Clone for Hnsw {
    /// Cheap structural clone: the chunked node storage is shared
    /// copy-on-write with the original (see [`ChunkedVec`]), so this costs
    /// O(n / CHUNK) `Arc` copies, not a deep copy of every link list.
    /// Transient search scratch (visited marks, frontier buffer) is not
    /// carried over — it is rebuilt lazily and never observable.
    fn clone(&self) -> Hnsw {
        Hnsw {
            params: self.params,
            nodes: self.nodes.clone(),
            entry: self.entry,
            rng: self.rng.clone(),
            mult: self.mult,
            dist_calls: self.dist_calls,
            batch_evals: self.batch_evals,
            visited_mark: Vec::new(),
            epoch: 0,
            scratch: Vec::new(),
            scratch_d: Vec::new(),
        }
    }
}

impl Hnsw {
    pub fn new(params: HnswParams) -> Self {
        let mult = 1.0 / (params.m.max(2) as f64).ln();
        Hnsw {
            rng: Rng::new(params.seed),
            params,
            nodes: ChunkedVec::new(),
            entry: None,
            mult,
            dist_calls: 0,
            batch_evals: 0,
            visited_mark: Vec::new(),
            epoch: 0,
            scratch: Vec::new(),
            scratch_d: Vec::new(),
        }
    }

    /// Start a new visited-set epoch and make sure marks cover all nodes.
    #[inline]
    fn next_epoch(&mut self) -> u32 {
        if self.visited_mark.len() < self.nodes.len() {
            self.visited_mark.resize(self.nodes.len(), 0);
        }
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            // wrapped: clear stale marks so epoch 0 values can't collide
            self.visited_mark.fill(0);
            self.epoch = 1;
        }
        self.epoch
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn params(&self) -> &HnswParams {
        &self.params
    }

    /// Total distance evaluations performed during construction (the
    /// paper's cost model — Fig 1 / Fig 2 report runtimes dominated by
    /// distance calls).
    pub fn dist_calls(&self) -> u64 {
        self.dist_calls
    }

    /// Batched distance dispatches performed during construction (each
    /// covering many pairwise evaluations, all of which are individually
    /// counted in [`Hnsw::dist_calls`]). Telemetry for "is the batch hot
    /// path actually in use" — not persisted.
    pub fn batch_evals(&self) -> u64 {
        self.batch_evals
    }

    /// Top level of the hierarchy (None when empty).
    pub fn top_level(&self) -> Option<usize> {
        self.entry.map(|e| self.nodes[e as usize].level())
    }

    /// Neighbor list of `id` at `level` (introspection / tests).
    pub fn neighbors(&self, id: u32, level: usize) -> &[u32] {
        let n = &self.nodes[id as usize];
        assert!(level < n.n_levels(), "level {level} out of range for {id}");
        n.links(level)
    }

    /// Level of node `id`.
    pub fn node_level(&self, id: u32) -> usize {
        self.nodes[id as usize].level()
    }

    /// Full structural state for persistence (see `persist` module).
    pub fn export(&self) -> HnswExport {
        HnswExport {
            params: self.params,
            links: self.nodes.iter().map(|n| n.to_lists()).collect(),
            entry: self.entry,
            rng_state: self.rng.state(),
            dist_calls: self.dist_calls,
        }
    }

    /// Rebuild an index from [`Hnsw::export`]ed state. The reloaded index
    /// continues *exactly* where the original left off (same RNG stream,
    /// same adjacency, same counters) and chunks its node storage exactly
    /// like the original run did (the layout is a pure function of the
    /// node sequence).
    pub fn import(e: HnswExport) -> Self {
        let mult = 1.0 / (e.params.m.max(2) as f64).ln();
        Hnsw {
            rng: Rng::from_state(e.rng_state),
            nodes: ChunkedVec::from_vec(
                e.links
                    .iter()
                    .map(|lists| Node::from_lists(lists, e.params.m))
                    .collect(),
            ),
            params: e.params,
            entry: e.entry,
            mult,
            dist_calls: e.dist_calls,
            batch_evals: 0,
            visited_mark: Vec::new(),
            epoch: 0,
            scratch: Vec::new(),
            scratch_d: Vec::new(),
        }
    }

    /// Copied-vs-shared chunk accounting for the node store against an
    /// earlier clone of this index (snapshot capture bookkeeping; bytes
    /// approximate the link-list heap of the copied chunks).
    pub fn node_chunk_delta(&self, prev: Option<&Hnsw>) -> ChunkDelta {
        self.nodes.chunk_delta(prev.map(|p| &p.nodes), |chunk| {
            chunk
                .iter()
                .map(|n| {
                    std::mem::size_of::<Node>()
                        + n.data.len() * std::mem::size_of::<u32>()
                })
                .sum()
        })
    }

    fn random_level(&mut self) -> usize {
        let u = self.rng.f64().max(1e-300);
        ((-u.ln()) * self.mult).floor() as usize
    }

    /// The single choke point every user distance flows through on the
    /// build path: [`sanitize_distance`] maps `NaN`/`-inf` to `+inf` here,
    /// so the neighbor heaps, the core-distance mirror, and Kruskal's
    /// `total_cmp` order downstream only ever see well-ordered values.
    #[inline]
    fn eval<T, S: ItemStore<T> + ?Sized, M: Metric<T>>(
        &mut self,
        items: &S,
        metric: &M,
        a: u32,
        b: u32,
        log: &mut DistLog,
    ) -> f64 {
        let d =
            sanitize_distance(metric.dist(items.get(a as usize), items.get(b as usize)));
        self.dist_calls += 1;
        log.push((a, b, d));
        d
    }

    /// Batched twin of [`Hnsw::eval`]: evaluate `fixed` against every id
    /// in `ids` with **one** [`Metric::distance_batch`] dispatch, then
    /// apply the same per-element choke-point duties — sanitize, count
    /// into `dist_calls`, append to the eval log (`(fixed, id)` order
    /// when `fixed_first`, `(id, fixed)` otherwise, matching what the
    /// scalar call sites logged). `out` holds the sanitized distances,
    /// index-aligned with `ids`.
    fn eval_batch<T, S: ItemStore<T> + ?Sized, M: Metric<T>>(
        &mut self,
        items: &S,
        metric: &M,
        fixed: u32,
        ids: &[u32],
        fixed_first: bool,
        out: &mut Vec<f64>,
        log: &mut DistLog,
    ) {
        out.clear();
        if ids.is_empty() {
            return;
        }
        out.resize(ids.len(), 0.0);
        let refs: Vec<&T> = ids.iter().map(|&id| items.get(id as usize)).collect();
        metric.distance_batch(items.get(fixed as usize), &refs, out);
        self.dist_calls += ids.len() as u64;
        self.batch_evals += 1;
        for (i, &id) in ids.iter().enumerate() {
            let d = sanitize_distance(out[i]);
            out[i] = d;
            log.push(if fixed_first { (fixed, id, d) } else { (id, fixed, d) });
        }
    }

    /// Insert the item with id `new_id` (ids must be dense: `new_id ==
    /// self.len()`; the caller owns the item store and must have pushed the
    /// item already). Every distance computed is appended to `log`;
    /// FISHDBC consumes these as candidate MST edges.
    ///
    /// Returns the closest discovered neighbors (up to `ef`), best-first.
    pub fn add<T, S: ItemStore<T> + ?Sized, M: Metric<T>>(
        &mut self,
        items: &S,
        metric: &M,
        new_id: u32,
        log: &mut DistLog,
    ) -> Vec<(u32, f64)> {
        assert_eq!(new_id as usize, self.nodes.len(), "ids must be dense");
        assert!((new_id as usize) < items.len(), "item must be pushed first");
        let level = self.random_level();
        self.nodes.push(Node::with_capacity(level, self.params.m));

        let Some(entry) = self.entry else {
            self.entry = Some(new_id);
            return Vec::new();
        };

        let top = self.nodes[entry as usize].level();
        let d0 = self.eval(items, metric, entry, new_id, log);
        let mut ep: Vec<(u32, f64)> = vec![(entry, d0)];

        // greedy descent through levels above the new node's level
        let mut l = top;
        while l > level {
            ep = self.search_layer(items, metric, new_id, ep, 1, l, log);
            l -= 1;
        }

        // insertion levels (top-down): beam search + heuristic linking
        let mut l = level.min(top);
        loop {
            let mut w =
                self.search_layer(items, metric, new_id, ep, self.params.ef, l, log);
            w.sort_unstable_by(|x, y| x.1.total_cmp(&y.1));
            let m_max = if l == 0 { self.params.m * 2 } else { self.params.m };
            let selected =
                self.select_heuristic(items, metric, &w, self.params.m, log);
            for &(nb, _) in &selected {
                self.link(items, metric, new_id, nb, l, m_max, log);
            }
            ep = w;
            if l == 0 {
                break;
            }
            l -= 1;
        }

        if level > top {
            self.entry = Some(new_id);
        }

        let mut out = ep;
        out.sort_unstable_by(|x, y| x.1.total_cmp(&y.1));
        out
    }

    /// k-nearest-neighbor **query** (no insertion, no logging): FISHDBC
    /// never queries during the build (paper §3), but a built index is a
    /// perfectly good ANN structure — the coordinator uses this to classify
    /// new items against the latest clustering without mutating state.
    ///
    /// Returns up to `k` `(id, distance)` pairs, ascending distance.
    pub fn search<T, S: ItemStore<T> + ?Sized, M: Metric<T>>(
        &self,
        items: &S,
        metric: &M,
        query: &T,
        k: usize,
        ef: usize,
    ) -> Vec<(u32, f64)> {
        self.search_filtered(items, metric, query, k, ef, |_| true)
    }

    /// [`Hnsw::search`] with a result filter: nodes failing `accept` are
    /// still **traversed** (they keep the graph navigable — this is how
    /// tombstoned items stay routable after an incremental deletion) but
    /// are never returned and never count toward the `ef` result beam.
    /// With an all-accepting filter this is exactly `search`, step for
    /// step. When almost everything is filtered out the beam cannot fill,
    /// so the search degrades toward a full component walk — the engine
    /// bounds that regime by compacting shards once the tombstone ratio
    /// crosses `EngineConfig::compact_at`.
    pub fn search_filtered<T, S: ItemStore<T> + ?Sized, M: Metric<T>>(
        &self,
        items: &S,
        metric: &M,
        query: &T,
        k: usize,
        ef: usize,
        accept: impl Fn(u32) -> bool,
    ) -> Vec<(u32, f64)> {
        let Some(entry) = self.entry else { return Vec::new() };
        // same sanitizing choke point as `eval`, for the query path (the
        // engine's bridge searches and online labels run through here);
        // `query_batch` applies it per element on the batched dispatches
        let qd =
            |id: u32| sanitize_distance(metric.dist(query, items.get(id as usize)));
        let mut dists: Vec<f64> = Vec::new();

        // greedy descent to level 1: each pass batches the current best's
        // whole neighbor list, then folds with the same strict `<` the
        // scalar loop used (first minimum wins ties — identical walk)
        let mut best = (entry, qd(entry));
        let top = self.nodes[entry as usize].level();
        for l in (1..=top).rev() {
            loop {
                let nbs = self.nodes[best.0 as usize].links(l);
                query_batch(items, metric, query, nbs, &mut dists);
                let mut improved = false;
                for (i, &nb) in nbs.iter().enumerate() {
                    if dists[i] < best.1 {
                        best = (nb, dists[i]);
                        improved = true;
                    }
                }
                if !improved {
                    break;
                }
            }
        }

        // beam search at level 0 (rejected nodes feed `cands` so the walk
        // can route *through* them, but never enter `results`); unvisited
        // neighbors are collected per node and evaluated with one batched
        // dispatch, heap updates replaying in scalar order
        use std::cmp::Reverse;
        use std::collections::BinaryHeap;
        let ef = ef.max(k);
        let mut visited: std::collections::HashSet<u32> =
            std::iter::once(best.0).collect();
        let mut frontier: Vec<u32> = Vec::new();
        let mut cands = BinaryHeap::from([Reverse((OrdF64(best.1), best.0))]);
        let mut results = BinaryHeap::new();
        if accept(best.0) {
            results.push((OrdF64(best.1), best.0));
        }
        while let Some(Reverse((OrdF64(cd), c))) = cands.pop() {
            let worst = results.peek().map_or(f64::INFINITY, |&(OrdF64(d), _)| d);
            if cd > worst && results.len() >= ef {
                break;
            }
            frontier.clear();
            for &nb in self.nodes[c as usize].links(0) {
                if visited.insert(nb) {
                    frontier.push(nb);
                }
            }
            query_batch(items, metric, query, &frontier, &mut dists);
            for (i, &nb) in frontier.iter().enumerate() {
                let d = dists[i];
                let worst =
                    results.peek().map_or(f64::INFINITY, |&(OrdF64(w), _)| w);
                if results.len() < ef || d < worst {
                    cands.push(Reverse((OrdF64(d), nb)));
                    if accept(nb) {
                        results.push((OrdF64(d), nb));
                        if results.len() > ef {
                            results.pop();
                        }
                    }
                }
            }
        }
        let mut out: Vec<(u32, f64)> =
            results.into_iter().map(|(OrdF64(d), id)| (id, d)).collect();
        out.sort_unstable_by(|x, y| x.1.total_cmp(&y.1));
        out.truncate(k);
        out
    }

    /// Beam search on one layer. `ep`: entry points with known distances to
    /// the query node `q_id`. Returns up to `ef` closest, unsorted.
    fn search_layer<T, S: ItemStore<T> + ?Sized, M: Metric<T>>(
        &mut self,
        items: &S,
        metric: &M,
        q_id: u32,
        ep: Vec<(u32, f64)>,
        ef: usize,
        level: usize,
        log: &mut DistLog,
    ) -> Vec<(u32, f64)> {
        use std::cmp::Reverse;
        use std::collections::BinaryHeap;

        let epoch = self.next_epoch();
        for &(id, _) in &ep {
            self.visited_mark[id as usize] = epoch;
        }
        // candidates: min-heap by distance; results: max-heap (worst on top)
        let mut cands: BinaryHeap<Reverse<(OrdF64, u32)>> =
            ep.iter().map(|&(id, d)| Reverse((OrdF64(d), id))).collect();
        let mut results: BinaryHeap<(OrdF64, u32)> =
            ep.into_iter().map(|(id, d)| (OrdF64(d), id)).collect();
        let mut scratch = std::mem::take(&mut self.scratch);
        let mut dists = std::mem::take(&mut self.scratch_d);

        while let Some(Reverse((OrdF64(cd), c))) = cands.pop() {
            let worst = results.peek().map_or(f64::INFINITY, |&(OrdF64(d), _)| d);
            if cd > worst && results.len() >= ef {
                break;
            }
            // collect unvisited neighbors into the reusable frontier buffer
            // (marks + scratch are disjoint fields, so no neighbor-list
            // clone), then evaluate the whole frontier with one batched
            // dispatch; the heap updates below replay per element in the
            // same order the scalar loop used, so results are unchanged
            scratch.clear();
            let node = &self.nodes[c as usize];
            if level < node.n_levels() {
                for &nb in node.links(level) {
                    if self.visited_mark[nb as usize] != epoch {
                        self.visited_mark[nb as usize] = epoch;
                        scratch.push(nb);
                    }
                }
            }
            self.eval_batch(items, metric, q_id, &scratch, false, &mut dists, log);
            for (i, &nb) in scratch.iter().enumerate() {
                let d = dists[i];
                let worst =
                    results.peek().map_or(f64::INFINITY, |&(OrdF64(w), _)| w);
                if results.len() < ef || d < worst {
                    cands.push(Reverse((OrdF64(d), nb)));
                    results.push((OrdF64(d), nb));
                    if results.len() > ef {
                        results.pop();
                    }
                }
            }
        }
        self.scratch = scratch;
        self.scratch_d = dists;
        results.into_iter().map(|(OrdF64(d), id)| (id, d)).collect()
    }

    /// Select-neighbors heuristic (Malkov & Yashunin Alg. 4,
    /// extendCandidates = false, keepPrunedConnections = true). `w` must be
    /// sorted by distance ascending. Distance calls between existing nodes
    /// are logged too — exactly the "farther away item" information FISHDBC
    /// needs to keep local clusters connected (paper §3.1).
    ///
    /// Deliberately **scalar**: the diversity check early-exits as soon as
    /// one selected neighbor refutes a candidate, so pre-batching every
    /// candidate×selected pair would evaluate up to `m`× more distances —
    /// the wrong trade under the paper's cost model (distance calls *are*
    /// the runtime). The batched select-neighbors work lives in
    /// [`Hnsw::shrink`], whose candidate distances have no early exit.
    fn select_heuristic<T, S: ItemStore<T> + ?Sized, M: Metric<T>>(
        &mut self,
        items: &S,
        metric: &M,
        w: &[(u32, f64)],
        m: usize,
        log: &mut DistLog,
    ) -> Vec<(u32, f64)> {
        let mut result: Vec<(u32, f64)> = Vec::with_capacity(m);
        let mut pruned: Vec<(u32, f64)> = Vec::new();
        for &(c, dq) in w {
            if result.len() >= m {
                break;
            }
            // diversity criterion: keep c iff it is closer to the query
            // than to every already-selected neighbor
            let mut ok = true;
            for &(r, _) in &result {
                let d = self.eval(items, metric, c, r, log);
                if d < dq {
                    ok = false;
                    break;
                }
            }
            if ok {
                result.push((c, dq));
            } else {
                pruned.push((c, dq));
            }
        }
        // keepPrunedConnections: fill remaining slots with closest pruned
        for &(c, dq) in &pruned {
            if result.len() >= m {
                break;
            }
            result.push((c, dq));
        }
        result
    }

    /// Bidirectional link new_id <-> nb at `level`, shrinking nb's list
    /// back to `m_max` with the heuristic when it overflows. These are the
    /// only rewiring writes; they go through [`ChunkedVec::get_mut`], so a
    /// chunk that a frozen snapshot still references is copied exactly
    /// once, the first time one of its nodes is rewired.
    fn link<T, S: ItemStore<T> + ?Sized, M: Metric<T>>(
        &mut self,
        items: &S,
        metric: &M,
        new_id: u32,
        nb: u32,
        level: usize,
        m_max: usize,
        log: &mut DistLog,
    ) {
        self.nodes.get_mut(new_id as usize).push_link(level, nb);
        // read-only probe first: get_mut would copy-on-write nb's chunk
        // even on the branch that writes nothing
        if self.nodes[nb as usize].level() < level {
            return;
        }
        let overflow = {
            let nb_node = self.nodes.get_mut(nb as usize);
            nb_node.push_link(level, new_id);
            nb_node.len(level) > m_max
        };
        if overflow {
            self.shrink(items, metric, nb, level, m_max, log);
        }
    }

    /// Shrink `id`'s neighbor list at `level` to `m_max` via the heuristic.
    fn shrink<T, S: ItemStore<T> + ?Sized, M: Metric<T>>(
        &mut self,
        items: &S,
        metric: &M,
        id: u32,
        level: usize,
        m_max: usize,
        log: &mut DistLog,
    ) {
        let list: Vec<u32> = self.nodes[id as usize].links(level).to_vec();
        let mut dists = std::mem::take(&mut self.scratch_d);
        self.eval_batch(items, metric, id, &list, true, &mut dists, log);
        let mut with_d: Vec<(u32, f64)> =
            list.iter().zip(&dists).map(|(&nb, &d)| (nb, d)).collect();
        self.scratch_d = dists;
        with_d.sort_unstable_by(|x, y| x.1.total_cmp(&y.1));
        let selected = self.select_heuristic(items, metric, &with_d, m_max, log);
        let links: Vec<u32> = selected.into_iter().map(|(nb, _)| nb).collect();
        self.nodes.get_mut(id as usize).set_links(level, &links);
    }
}

/// Query-path twin of [`Hnsw::eval_batch`] (free function: the query path
/// is `&self`): one [`Metric::distance_batch`] dispatch, sanitized per
/// element — no logging and no counter, exactly like the scalar `qd`
/// closure it batches. `out` is index-aligned with `ids`.
fn query_batch<T, S: ItemStore<T> + ?Sized, M: Metric<T>>(
    items: &S,
    metric: &M,
    query: &T,
    ids: &[u32],
    out: &mut Vec<f64>,
) {
    out.clear();
    if ids.is_empty() {
        return;
    }
    out.resize(ids.len(), 0.0);
    let refs: Vec<&T> = ids.iter().map(|&id| items.get(id as usize)).collect();
    metric.distance_batch(query, &refs, out);
    for d in out.iter_mut() {
        *d = sanitize_distance(*d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::vector::euclidean;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn metric() -> impl Metric<Vec<f32>> {
        |a: &Vec<f32>, b: &Vec<f32>| euclidean(a, b)
    }

    fn build(
        items: &[Vec<f32>],
        params: HnswParams,
    ) -> (Hnsw, DistLog) {
        let m = metric();
        let mut h = Hnsw::new(params);
        let mut log = DistLog::new();
        for i in 0..items.len() {
            h.add(items, &m, i as u32, &mut log);
        }
        (h, log)
    }

    fn random_points(rng: &mut Rng, n: usize, d: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| (0..d).map(|_| rng.normal() as f32).collect())
            .collect()
    }

    #[test]
    fn empty_and_single() {
        let m = metric();
        let mut h = Hnsw::new(HnswParams::default());
        assert!(h.is_empty());
        let items = vec![vec![0.0f32]];
        let mut log = DistLog::new();
        let found = h.add(&items, &m, 0, &mut log);
        assert!(found.is_empty());
        assert!(log.is_empty());
        assert_eq!(h.len(), 1);
        assert_eq!(h.top_level(), Some(h.node_level(0)));
    }

    #[test]
    fn finds_true_nearest_neighbors_small() {
        // with ef >= n the search is exhaustive-ish: recall should be perfect
        let mut rng = Rng::new(42);
        let items = random_points(&mut rng, 60, 4);
        let (h, _) = build(&items, HnswParams { m: 8, ef: 60, seed: 7 });
        assert_eq!(h.len(), 60);

        // check the last-inserted node's returned neighbors vs brute force
        let m = metric();
        let mut h2 = Hnsw::new(HnswParams { m: 8, ef: 60, seed: 7 });
        let mut log = DistLog::new();
        let mut found = Vec::new();
        for i in 0..items.len() {
            found = h2.add(&items, &m, i as u32, &mut log);
        }
        let q = items.len() - 1;
        let mut brute: Vec<(u32, f64)> = (0..q)
            .map(|j| (j as u32, euclidean(&items[q], &items[j])))
            .collect();
        brute.sort_unstable_by(|x, y| x.1.total_cmp(&y.1));
        let top5: std::collections::HashSet<u32> =
            brute.iter().take(5).map(|&(id, _)| id).collect();
        let found5: std::collections::HashSet<u32> =
            found.iter().take(5).map(|&(id, _)| id).collect();
        let overlap = top5.intersection(&found5).count();
        assert!(overlap >= 4, "recall@5 too low: {overlap}/5");
    }

    #[test]
    fn log_contains_valid_triples() {
        let mut rng = Rng::new(1);
        let items = random_points(&mut rng, 40, 3);
        let (h, log) = build(&items, HnswParams { m: 5, ef: 10, seed: 3 });
        assert_eq!(h.dist_calls() as usize, log.len());
        assert!(!log.is_empty());
        for &(a, b, d) in &log {
            assert!(a != b, "self-distance logged");
            assert!((a as usize) < items.len() && (b as usize) < items.len());
            let expect = euclidean(&items[a as usize], &items[b as usize]);
            assert!((d - expect).abs() < 1e-12, "logged distance wrong");
        }
    }

    #[test]
    fn degree_bounds_respected() {
        let mut rng = Rng::new(5);
        let items = random_points(&mut rng, 200, 3);
        let params = HnswParams { m: 6, ef: 20, seed: 11 };
        let (h, _) = build(&items, params);
        for id in 0..h.len() as u32 {
            for l in 0..=h.node_level(id) {
                let deg = h.neighbors(id, l).len();
                let m_max = if l == 0 { params.m * 2 } else { params.m };
                assert!(deg <= m_max, "node {id} level {l} degree {deg} > {m_max}");
            }
        }
    }

    #[test]
    fn links_are_bidirectional_on_shared_levels() {
        let mut rng = Rng::new(9);
        let items = random_points(&mut rng, 100, 3);
        let (h, _) = build(&items, HnswParams { m: 5, ef: 15, seed: 13 });
        // graph connectivity sanity at level 0: every node has >= 1 link
        // (except possibly the very first in degenerate cases)
        let isolated = (0..h.len() as u32)
            .filter(|&id| h.neighbors(id, 0).is_empty())
            .count();
        assert!(isolated == 0, "{isolated} isolated nodes at level 0");
    }

    #[test]
    fn level_distribution_is_geometric_ish() {
        let mut rng = Rng::new(17);
        let items = random_points(&mut rng, 2000, 2);
        let (h, _) = build(&items, HnswParams { m: 10, ef: 10, seed: 23 });
        let lvl0 = (0..h.len() as u32).filter(|&i| h.node_level(i) == 0).count();
        // with mL = 1/ln(10), P(level 0) = 1 - e^{-ln 10} = 0.9
        let frac = lvl0 as f64 / h.len() as f64;
        assert!((0.85..0.95).contains(&frac), "level-0 fraction {frac}");
    }

    #[test]
    fn prop_construction_cost_subquadratic() {
        // distance calls per item should not blow up with n (cost model)
        check("hnsw-cost", 3, |rng, case| {
            let n = 300 * (case + 1);
            let items = random_points(rng, n, 4);
            let (h, _) = build(&items, HnswParams { m: 5, ef: 10, seed: 1 });
            let per_item = h.dist_calls() as f64 / n as f64;
            assert!(
                per_item < 250.0,
                "n={n}: {per_item} dist calls/item looks quadratic"
            );
        });
    }

    #[test]
    fn search_matches_brute_force_on_small_sets() {
        let mut rng = Rng::new(77);
        let items = random_points(&mut rng, 120, 4);
        let (h, _) = build(&items, HnswParams { m: 8, ef: 40, seed: 5 });
        let m = metric();
        let mut hits = 0;
        let queries = random_points(&mut rng, 20, 4);
        for q in &queries {
            let got = h.search(&items, &m, q, 5, 60);
            assert_eq!(got.len(), 5);
            assert!(got.windows(2).all(|w| w[0].1 <= w[1].1), "unsorted");
            let mut brute: Vec<(u32, f64)> = (0..items.len())
                .map(|j| (j as u32, euclidean(q, &items[j])))
                .collect();
            brute.sort_unstable_by(|x, y| x.1.total_cmp(&y.1));
            let want: std::collections::HashSet<u32> =
                brute.iter().take(5).map(|&(i, _)| i).collect();
            hits += got.iter().filter(|&&(i, _)| want.contains(&i)).count();
        }
        assert!(hits >= 90, "recall@5 {}%", hits);
    }

    #[test]
    fn search_does_not_log_or_mutate() {
        let mut rng = Rng::new(78);
        let items = random_points(&mut rng, 80, 3);
        let (h, log) = build(&items, HnswParams { m: 5, ef: 15, seed: 6 });
        let calls_before = h.dist_calls();
        let m = metric();
        let _ = h.search(&items, &m, &items[0], 3, 20);
        assert_eq!(h.dist_calls(), calls_before);
        assert_eq!(log.len(), calls_before as usize);
    }

    #[test]
    fn search_filtered_skips_rejected_but_stays_navigable() {
        let mut rng = Rng::new(79);
        let items = random_points(&mut rng, 150, 3);
        let (h, _) = build(&items, HnswParams { m: 6, ef: 20, seed: 8 });
        let m = metric();
        let q = &items[0];

        // an all-accepting filter is exactly `search`
        let plain = h.search(&items, &m, q, 5, 30);
        let all = h.search_filtered(&items, &m, q, 5, 30, |_| true);
        assert_eq!(plain, all, "all-true filter must not change the search");

        // rejecting the even ids: results contain only odd ids, and the
        // beam still finds k of them by routing through rejected nodes
        let odd = h.search_filtered(&items, &m, q, 5, 30, |id| id % 2 == 1);
        assert_eq!(odd.len(), 5);
        assert!(odd.iter().all(|&(id, _)| id % 2 == 1), "filter leaked: {odd:?}");
        assert!(odd.windows(2).all(|w| w[0].1 <= w[1].1), "unsorted");

        // rejecting everything returns nothing (and terminates)
        let none = h.search_filtered(&items, &m, q, 5, 30, |_| false);
        assert!(none.is_empty());
    }

    #[test]
    fn search_on_empty_index() {
        let h = Hnsw::new(HnswParams::default());
        let m = metric();
        let items: Vec<Vec<f32>> = vec![];
        assert!(h.search(&items, &m, &vec![1.0f32], 3, 10).is_empty());
    }

    #[test]
    #[should_panic(expected = "dense")]
    fn non_dense_ids_rejected() {
        let m = metric();
        let mut h = Hnsw::new(HnswParams::default());
        let items = vec![vec![0.0f32], vec![1.0f32]];
        let mut log = DistLog::new();
        h.add(&items, &m, 1, &mut log); // skips id 0
    }

    #[test]
    fn prop_snapshot_equivalence_chunked_vs_dense() {
        // The copy-on-write refactor must be invisible: build two indexes
        // with identical parameters over the same stream, but on one of
        // them take `clone()` snapshots at random points and KEEP them
        // alive — forcing every later rewire of a shared chunk through the
        // copy-on-write path. The final exports must be bit-identical,
        // every frozen snapshot must still export exactly what it captured,
        // and snapshot searches must match a dense rebuild (import of the
        // capture-time export) query for query.
        check("hnsw-snapshot-equivalence", 4, |rng, case| {
            let n = 150 + case * 70;
            let items = random_points(rng, n, 3);
            let params = HnswParams { m: 6, ef: 12, seed: 31 + case as u64 };
            let m = metric();
            let mut plain = Hnsw::new(params);
            let mut cow = Hnsw::new(params);
            let mut log = DistLog::new();
            let mut snaps: Vec<(usize, Hnsw, HnswExport)> = Vec::new();
            for i in 0..n {
                plain.add(&items, &m, i as u32, &mut log);
                cow.add(&items, &m, i as u32, &mut log);
                if rng.below(10) == 0 {
                    let snap = cow.clone();
                    let export_now = snap.export();
                    snaps.push((i + 1, snap, export_now));
                }
            }
            assert!(!snaps.is_empty(), "degenerate case: no snapshots taken");
            assert_eq!(
                plain.export(),
                cow.export(),
                "held snapshots perturbed construction"
            );
            for (n_at, snap, export_at) in &snaps {
                assert_eq!(&snap.export(), export_at, "frozen snapshot drifted");
                let dense = Hnsw::import(export_at.clone());
                for _ in 0..3 {
                    let q = &items[rng.below(n)];
                    let got = snap.search(&items[..*n_at], &m, q, 5, 20);
                    let want = dense.search(&items[..*n_at], &m, q, 5, 20);
                    assert_eq!(got, want, "snapshot search diverged at {n_at}");
                }
            }
        });
    }

    #[test]
    fn flat_node_links_roundtrip() {
        // the flat inline-capacity layout behaves exactly like the nested
        // lists it replaced: per-level push/set/read plus list round-trip
        let mut n = Node::with_capacity(2, 3);
        assert_eq!(n.level(), 2);
        for l in 0..=2 {
            assert!(n.links(l).is_empty());
        }
        n.push_link(0, 4);
        n.push_link(0, 9);
        n.push_link(2, 7);
        assert_eq!(n.links(0), &[4, 9]);
        assert!(n.links(1).is_empty());
        assert_eq!(n.links(2), &[7]);
        n.set_links(0, &[1, 2, 3]);
        assert_eq!(n.links(0), &[1, 2, 3]);
        let lists = n.to_lists();
        assert_eq!(lists, vec![vec![1, 2, 3], vec![], vec![7]]);
        let back = Node::from_lists(&lists, 3);
        assert_eq!(back.to_lists(), lists);
        // capacity slack: level 0 admits the m_max+1 = 2m+1 transient that
        // link() creates right before shrink() restores the bound
        let mut f = Node::with_capacity(0, 2);
        for v in 0..5u32 {
            f.push_link(0, v);
        }
        assert_eq!(f.links(0), &[0, 1, 2, 3, 4]);
    }

    #[test]
    fn build_exercises_the_batch_path() {
        let mut rng = Rng::new(21);
        let items = random_points(&mut rng, 60, 3);
        let (h, log) = build(&items, HnswParams { m: 5, ef: 10, seed: 3 });
        assert!(h.batch_evals() > 0, "construction never batched");
        assert!(
            h.batch_evals() < h.dist_calls(),
            "batches must cover many pairwise evals"
        );
        assert_eq!(h.dist_calls() as usize, log.len());
        // clones carry the counter; imports restart it (not persisted)
        assert_eq!(h.clone().batch_evals(), h.batch_evals());
        assert_eq!(Hnsw::import(h.export()).batch_evals(), 0);
    }

    #[test]
    fn prop_export_roundtrip_identity_and_identical_continuation() {
        // export → import → export is the identity (neighbors() and search
        // read the same adjacency), and a resumed index keeps adding items
        // exactly like the uninterrupted one even while old clones pin the
        // pre-split chunks.
        check("hnsw-export-roundtrip", 3, |rng, case| {
            let n = 120 + case * 60;
            let items = random_points(rng, n + 80, 3);
            let m = metric();
            let params = HnswParams { m: 5, ef: 15, seed: 7 + case as u64 };
            let mut h = Hnsw::new(params);
            let mut log = DistLog::new();
            for i in 0..n {
                h.add(&items, &m, i as u32, &mut log);
            }
            let e1 = h.export();
            let resumed = Hnsw::import(e1.clone());
            assert_eq!(resumed.export(), e1, "roundtrip not the identity");
            for id in 0..n as u32 {
                for l in 0..=h.node_level(id) {
                    assert_eq!(h.neighbors(id, l), resumed.neighbors(id, l));
                }
            }
            // pin the old chunks, then continue on both sides
            let _pin = (h.clone(), resumed.clone());
            let mut resumed = resumed;
            for i in n..n + 80 {
                h.add(&items, &m, i as u32, &mut log);
                resumed.add(&items, &m, i as u32, &mut log);
            }
            assert_eq!(h.export(), resumed.export(), "continuation diverged");
        });
    }
}
