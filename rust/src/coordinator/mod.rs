//! Streaming coordinator: the service layer that makes FISHDBC's
//! incrementality operational (paper §1: "in a streaming context, new data
//! can be added as they arrive, and clustering can be computed
//! inexpensively").
//!
//! This is the **single-shard reference path**: one worker thread owns one
//! `Fishdbc`, so ingest throughput is capped at one core of HNSW insertion.
//! For multi-core ingest use [`crate::engine::Engine`], which runs S of
//! these per-shard states in parallel and merges their spanning forests
//! into one global clustering; the coordinator remains the simplest
//! deployment and the equivalence baseline the engine is tested against.
//!
//! Architecture (thread-based; the offline image has no async runtime —
//! see DESIGN.md §Dependency-policy):
//!
//! * a dedicated **worker thread** owns the `Fishdbc` state and processes
//!   commands from a **bounded** channel — the bound is the backpressure
//!   mechanism: producers block when ingestion outruns clustering;
//! * **ingestion** sends batches of items; the worker coalesces
//!   consecutive queued batches before bookkeeping (micro-batching);
//! * **re-clustering** runs either on demand (`cluster()`) or
//!   automatically every `recluster_every` items; the latest clustering
//!   snapshot is shared via `latest()` without blocking ingestion;
//! * the MSF → dendrogram → condensed tree → extraction back half runs
//!   through the same memoizing [`Pipeline`](crate::engine::pipeline) as
//!   the sharded engine, so a re-cluster whose forest did not change
//!   short-circuits, and a changed `mcs` reuses the cached dendrogram.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::distances::{Item, MetricKind};
use crate::engine::pipeline::{Pipeline, PipelineStats};
use crate::fishdbc::{Fishdbc, FishdbcParams, FishdbcStats};
use crate::hdbscan::Clustering;

/// Coordinator configuration.
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    pub fishdbc: FishdbcParams,
    /// Minimum cluster size used for automatic re-clusterings.
    pub mcs: usize,
    /// Re-cluster automatically after this many new items (0 = never).
    pub recluster_every: usize,
    /// Command-queue bound (backpressure depth), in batches.
    pub queue_depth: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            fishdbc: FishdbcParams::default(),
            mcs: 10,
            recluster_every: 0,
            queue_depth: 16,
        }
    }
}

/// A clustering snapshot with provenance.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub clustering: Clustering,
    /// Items in the index when the snapshot was taken.
    pub n_items: usize,
    /// Seconds spent extracting it (the paper's "cluster" column).
    pub extract_secs: f64,
}

/// Counters exported by the coordinator.
#[derive(Clone, Copy, Debug, Default)]
pub struct CoordinatorStats {
    pub fishdbc: FishdbcStats,
    pub batches: u64,
    pub reclusters: u64,
    /// Total wall time spent inserting items (the paper's "build" column).
    pub build_secs: f64,
    /// Shared extraction-pipeline counters (runs, cache hits, stage time).
    pub pipeline: PipelineStats,
}

enum Command {
    AddBatch(Vec<Item>),
    Cluster { mcs: usize, reply: SyncSender<Snapshot> },
    Classify { items: Vec<Item>, k: usize, reply: SyncSender<Vec<i32>> },
    Stats { reply: SyncSender<CoordinatorStats> },
    Shutdown,
}

/// Handle to a running coordinator. Dropping it shuts the worker down.
pub struct Coordinator {
    tx: SyncSender<Command>,
    worker: Option<JoinHandle<()>>,
    latest: Arc<Mutex<Option<Snapshot>>>,
    queued: Arc<AtomicU64>,
}

impl Coordinator {
    /// Spawn a coordinator clustering [`Item`]s under `metric`.
    pub fn spawn(metric: MetricKind, config: CoordinatorConfig) -> Coordinator {
        let (tx, rx) = sync_channel::<Command>(config.queue_depth.max(1));
        let latest = Arc::new(Mutex::new(None));
        let queued = Arc::new(AtomicU64::new(0));
        let worker = {
            let latest = Arc::clone(&latest);
            let queued = Arc::clone(&queued);
            std::thread::Builder::new()
                .name("fishdbc-coordinator".into())
                .spawn(move || Worker::new(metric, config, latest, queued).run(rx))
                .expect("spawn coordinator worker")
        };
        Coordinator { tx, worker: Some(worker), latest, queued }
    }

    /// Enqueue a batch of items (blocks when the queue is full —
    /// backpressure). Items incompatible with the coordinator's metric
    /// make the worker panic; validate with [`MetricKind::compatible`]
    /// first when ingesting untrusted data.
    pub fn add_batch(&self, items: Vec<Item>) {
        if items.is_empty() {
            return;
        }
        self.queued.fetch_add(1, Ordering::Relaxed);
        self.tx.send(Command::AddBatch(items)).expect("coordinator gone");
    }

    /// Request a fresh clustering (blocking until extracted).
    pub fn cluster(&self, mcs: usize) -> Snapshot {
        let (reply, rx) = sync_channel(1);
        self.tx.send(Command::Cluster { mcs, reply }).expect("coordinator gone");
        rx.recv().expect("coordinator gone")
    }

    /// Classify external items against the latest clustering *without*
    /// inserting them: majority vote among each item's k nearest clustered
    /// neighbors (see [`crate::fishdbc::Fishdbc::classify`]). Takes a fresh
    /// snapshot first if none exists yet. Returns one label per item
    /// (-1 = noise/unknown).
    pub fn classify(&self, items: Vec<Item>, k: usize) -> Vec<i32> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Command::Classify { items, k, reply })
            .expect("coordinator gone");
        rx.recv().expect("coordinator gone")
    }

    /// Latest snapshot (on-demand or automatic), non-blocking.
    pub fn latest(&self) -> Option<Snapshot> {
        self.latest.lock().unwrap().clone()
    }

    /// Current counters. Blocking round-trip behind queued work, so this
    /// doubles as an ingestion barrier.
    pub fn stats(&self) -> CoordinatorStats {
        let (reply, rx) = sync_channel(1);
        self.tx.send(Command::Stats { reply }).expect("coordinator gone");
        rx.recv().expect("coordinator gone")
    }

    /// Batches currently waiting in the queue (approximate).
    pub fn queue_depth(&self) -> u64 {
        self.queued.load(Ordering::Relaxed)
    }

    /// Shut down, waiting for the worker to finish outstanding work.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Command::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Command::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

struct Worker {
    f: Fishdbc<Item, MetricKind>,
    metric: MetricKind,
    config: CoordinatorConfig,
    latest: Arc<Mutex<Option<Snapshot>>>,
    queued: Arc<AtomicU64>,
    pipeline: Pipeline,
    batches: u64,
    reclusters: u64,
    build_secs: f64,
    since_recluster: usize,
}

impl Worker {
    fn new(
        metric: MetricKind,
        config: CoordinatorConfig,
        latest: Arc<Mutex<Option<Snapshot>>>,
        queued: Arc<AtomicU64>,
    ) -> Worker {
        Worker {
            f: Fishdbc::new(metric, config.fishdbc),
            metric,
            config,
            latest,
            queued,
            pipeline: Pipeline::new(),
            batches: 0,
            reclusters: 0,
            build_secs: 0.0,
            since_recluster: 0,
        }
    }

    fn run(mut self, rx: Receiver<Command>) {
        let mut pending: Option<Command> = None;
        loop {
            let cmd = match pending.take() {
                Some(c) => c,
                None => match rx.recv() {
                    Ok(c) => c,
                    Err(_) => break,
                },
            };
            match cmd {
                Command::AddBatch(items) => {
                    let t0 = std::time::Instant::now();
                    self.ingest(items);
                    // micro-batching: coalesce already-queued adds
                    loop {
                        match rx.try_recv() {
                            Ok(Command::AddBatch(more)) => self.ingest(more),
                            Ok(other) => {
                                pending = Some(other);
                                break;
                            }
                            Err(TryRecvError::Empty | TryRecvError::Disconnected) => {
                                break
                            }
                        }
                    }
                    self.build_secs += t0.elapsed().as_secs_f64();
                    if self.config.recluster_every > 0
                        && self.since_recluster >= self.config.recluster_every
                    {
                        let snap = self.extract(self.config.mcs);
                        *self.latest.lock().unwrap() = Some(snap);
                        self.since_recluster = 0;
                    }
                }
                Command::Cluster { mcs, reply } => {
                    let snap = self.extract(mcs);
                    *self.latest.lock().unwrap() = Some(snap.clone());
                    let _ = reply.send(snap);
                }
                Command::Classify { items, k, reply } => {
                    // reuse the latest snapshot if it covers the current
                    // index; otherwise extract a fresh one
                    let snap = {
                        let cached = self.latest.lock().unwrap().clone();
                        match cached {
                            Some(s) if s.n_items == self.f.len() => s,
                            _ => {
                                let s = self.extract(self.config.mcs);
                                *self.latest.lock().unwrap() = Some(s.clone());
                                s
                            }
                        }
                    };
                    let labels: Vec<i32> = items
                        .iter()
                        .map(|it| {
                            self.f.classify(it, &snap.clustering.labels, k)
                        })
                        .collect();
                    let _ = reply.send(labels);
                }
                Command::Stats { reply } => {
                    let _ = reply.send(CoordinatorStats {
                        fishdbc: self.f.stats(),
                        batches: self.batches,
                        reclusters: self.reclusters,
                        build_secs: self.build_secs,
                        pipeline: self.pipeline.stats(),
                    });
                }
                Command::Shutdown => break,
            }
        }
    }

    fn ingest(&mut self, items: Vec<Item>) {
        self.queued.fetch_sub(1, Ordering::Relaxed);
        self.batches += 1;
        self.since_recluster += items.len();
        for it in items {
            assert!(
                self.metric.compatible(&it),
                "item incompatible with metric {}",
                self.metric.name()
            );
            self.f.add(it);
        }
    }

    fn extract(&mut self, mcs: usize) -> Snapshot {
        let t0 = std::time::Instant::now();
        // same computation as `Fishdbc::cluster`, but routed through the
        // shared memoizing pipeline: an unchanged forest short-circuits,
        // and a changed mcs reuses the cached dendrogram
        self.f.update_mst();
        let (clustering, _run) =
            self.pipeline.run(self.f.msf_edges(), self.f.len(), mcs, false);
        self.reclusters += 1;
        Snapshot {
            n_items: self.f.len(),
            clustering,
            extract_secs: t0.elapsed().as_secs_f64(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets;

    fn blob_items(n: usize) -> Vec<Item> {
        datasets::blobs::generate(n, 4, 3, 11).items
    }

    #[test]
    fn streamed_equals_batch_clustering() {
        let items = blob_items(300);

        // batch reference
        let mut f = Fishdbc::new(MetricKind::Euclidean, FishdbcParams::default());
        for it in items.clone() {
            f.add(it);
        }
        let want = f.cluster(10);

        // streamed through the coordinator in chunks
        let c =
            Coordinator::spawn(MetricKind::Euclidean, CoordinatorConfig::default());
        for chunk in items.chunks(37) {
            c.add_batch(chunk.to_vec());
        }
        let got = c.cluster(10);
        assert_eq!(got.n_items, 300);
        assert_eq!(got.clustering.labels, want.labels);
        c.shutdown();
    }

    #[test]
    fn auto_recluster_produces_snapshots() {
        let items = blob_items(250);
        let c = Coordinator::spawn(
            MetricKind::Euclidean,
            CoordinatorConfig { recluster_every: 100, ..Default::default() },
        );
        for chunk in items.chunks(50) {
            c.add_batch(chunk.to_vec());
            // pace the stream so batches are not all coalesced into one
            let _ = c.stats();
        }
        let stats = c.stats();
        assert!(stats.reclusters >= 2, "reclusters {}", stats.reclusters);
        let snap = c.latest().expect("snapshot");
        assert!(snap.n_items >= 200);
        assert!(snap.extract_secs >= 0.0);
        c.shutdown();
    }

    #[test]
    fn stats_reflect_progress() {
        let items = blob_items(120);
        let c =
            Coordinator::spawn(MetricKind::Euclidean, CoordinatorConfig::default());
        c.add_batch(items);
        let s = c.stats();
        assert_eq!(s.fishdbc.items, 120);
        assert!(s.fishdbc.dist_calls > 0);
        assert!(s.batches >= 1);
        assert!(s.build_secs > 0.0);
        c.shutdown();
    }

    #[test]
    fn empty_batches_are_noops() {
        let c =
            Coordinator::spawn(MetricKind::Euclidean, CoordinatorConfig::default());
        c.add_batch(vec![]);
        let s = c.stats();
        assert_eq!(s.fishdbc.items, 0);
        let snap = c.cluster(5);
        assert_eq!(snap.clustering.n_clusters, 0);
        c.shutdown();
    }

    #[test]
    fn classify_labels_new_items_without_inserting() {
        let items = blob_items(300);
        let c =
            Coordinator::spawn(MetricKind::Euclidean, CoordinatorConfig::default());
        c.add_batch(items.clone());
        let snap = c.cluster(10);
        assert!(snap.clustering.n_clusters >= 2);

        // classify copies of known items: must match their cluster labels
        let probe: Vec<Item> = items[..20].to_vec();
        let got = c.classify(probe, 5);
        let mut agree = 0;
        for (i, l) in got.iter().enumerate() {
            if *l == snap.clustering.labels[i] {
                agree += 1;
            }
        }
        assert!(agree >= 18, "classify agreed on {agree}/20");

        // classification must not have inserted anything
        assert_eq!(c.stats().fishdbc.items, 300);
        c.shutdown();
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let items = blob_items(60);
        {
            let c = Coordinator::spawn(
                MetricKind::Euclidean,
                CoordinatorConfig::default(),
            );
            c.add_batch(items);
        } // drop must join without deadlock
    }

    #[test]
    fn repeated_cluster_short_circuits_through_pipeline() {
        let items = blob_items(200);
        let c =
            Coordinator::spawn(MetricKind::Euclidean, CoordinatorConfig::default());
        c.add_batch(items);
        let a = c.cluster(10);
        let b = c.cluster(10);
        assert_eq!(a.clustering.labels, b.clustering.labels);
        // a different mcs on the same forest only redoes condense/extract
        let _ = c.cluster(5);
        let s = c.stats();
        assert_eq!(s.reclusters, 3);
        assert_eq!(s.pipeline.runs, 3);
        assert!(s.pipeline.short_circuits >= 1, "{:?}", s.pipeline);
        assert!(s.pipeline.dendrogram_reuses >= 1, "{:?}", s.pipeline);
        c.shutdown();
    }

    #[test]
    fn backpressure_queue_depth_visible() {
        let c = Coordinator::spawn(
            MetricKind::Euclidean,
            CoordinatorConfig { queue_depth: 4, ..Default::default() },
        );
        // big batches keep the worker busy long enough to see depth > 0
        for _ in 0..4 {
            c.add_batch(blob_items(400));
        }
        // by the time stats returns, everything must be ingested
        let s = c.stats();
        assert_eq!(s.fishdbc.items, 1600);
        assert_eq!(c.queue_depth(), 0);
        c.shutdown();
    }
}
